"""repro.api: engine parity, planner cost model, facade behavior.

The parity suite is the registry's contract: every registered engine must be
exact vs ``knn_brute`` on shared shapes, including the awkward ones — k
larger than some leaves, d not a multiple of the pad width, m smaller than
one query tile.  Planner tests pin the cost model (memory budget => chunk
count, device count => forest); the multi-device auto-plan runs in a
subprocess with forced host devices, like ``test_distributed.py``.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import (
    KNOWN_OPS,
    IndexSpec,
    KNNIndex,
    MutabilityError,
    OpUnsupported,
    QueryResult,
    RadiusResult,
    SearchStats,
    StatResult,
    available_engines,
    dualtree_cache_size,
    estimate_slab_bytes,
    get_engine,
    knn_brute,
    plan,
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

ALL_ENGINES = sorted(available_engines())

# (n, m, d, k, height) — shapes chosen to hit the contract's edge cases.
PARITY_SHAPES = [
    # baseline: everything comfortable
    pytest.param(4000, 300, 8, 10, 4, id="baseline"),
    # k > leaf size: h=6 over 700 pts => leaves of ~10-11, k=12 exceeds
    # most leaves (but not leaf_pad), so queries must merge across leaves
    pytest.param(700, 64, 4, 12, 6, id="k_gt_leaf"),
    # d=5: not a multiple of the kernel's 8-wide feature pad
    pytest.param(2500, 128, 5, 7, 3, id="d_odd"),
    # m=17 < tile_q and not a multiple of anything
    pytest.param(3000, 17, 8, 5, 4, id="m_lt_tile"),
]


def _data(n, m, d, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=(m, d)).astype(np.float32))


class TestEngineParity:
    @pytest.mark.parametrize("engine", ALL_ENGINES)
    @pytest.mark.parametrize("n,m,d,k,height", PARITY_SHAPES)
    def test_exact_vs_brute(self, engine, n, m, d, k, height):
        pts, q = _data(n, m, d, seed=hash((n, m, d)) % 1000)
        idx = KNNIndex.build(
            pts, spec=IndexSpec(engine=engine, height=height, k_hint=k,
                                tile_q=64)
        )
        res = idx.query(q, k=k)
        bd, bi = knn_brute(q, pts, k)
        np.testing.assert_allclose(res.dists, bd, rtol=1e-4, atol=1e-4)
        assert (res.idx == bi).mean() > 0.999  # ties may permute
        assert res.idx.dtype == np.int64
        assert res.engine == engine

    @pytest.mark.parametrize("engine", ALL_ENGINES)
    def test_tuple_unpacking_back_compat(self, engine):
        pts, q = _data(500, 20, 6, seed=3)
        idx = KNNIndex.build(pts, spec=IndexSpec(engine=engine, height=2))
        dists, ids = idx.query(q, k=3)
        bd, _ = knn_brute(q, pts, 3)
        np.testing.assert_allclose(dists, bd, rtol=1e-4, atol=1e-4)

    def test_capabilities_declared(self):
        caps = available_engines()
        for name in ("brute", "host", "chunked", "forest", "ring"):
            assert name in caps, f"issue-mandated engine {name} missing"
        assert all(c.exact for c in caps.values())
        assert caps["chunked"].out_of_core
        assert caps["forest"].multi_device and caps["ring"].multi_device
        assert not caps["brute"].needs_build

    def test_unknown_engine_rejected(self):
        with pytest.raises(KeyError, match="unknown engine"):
            get_engine("definitely_not_registered")


class TestMutabilityContract:
    """Caps-contract for incremental insert/delete: the parity suite above
    auto-discovers the ``dynamic`` engine from the registry; here we pin
    the other half of the contract — engines declaring ``mutable=False``
    must raise the TYPED error from the facade, never mutate silently."""

    def test_dynamic_engine_auto_discovered(self):
        caps = available_engines()
        assert "dynamic" in caps
        assert caps["dynamic"].mutable and caps["dynamic"].exact
        assert "dynamic" in ALL_ENGINES  # rode into the parity sweep above

    def test_exactly_one_mutable_engine_today(self):
        mutable = [n for n, c in available_engines().items() if c.mutable]
        assert mutable == ["dynamic"]

    @pytest.mark.parametrize(
        "engine",
        [n for n, c in available_engines().items() if not c.mutable],
    )
    def test_immutable_engines_raise_typed_error(self, engine):
        pts, _ = _data(600, 1, 6, seed=21)
        idx = KNNIndex.build(pts, spec=IndexSpec(engine=engine, height=2))
        with pytest.raises(MutabilityError):
            idx.insert(pts[:4])
        with pytest.raises(MutabilityError):
            idx.delete([0])
        assert idx.n == 600                      # nothing mutated

    def test_mutability_error_is_typed(self):
        # callers filter on the TYPE (a TypeError subclass), not on text
        assert issubclass(MutabilityError, TypeError)

    def test_mutable_spec_plans_dynamic_with_crossover(self):
        p = plan(50_000, 8, k=10, devices=[object()], mutable=True)
        assert p.engine == "dynamic"
        assert p.crossover_batch and p.crossover_batch > 0
        assert any("rebuild" in r and "crossover" in r for r in p.reasons)

    def test_mutable_multi_device_places_rungs(self):
        # the old rule forced mutable specs onto one device; now the
        # forest's shard rungs are PLACED across devices and the plan
        # records the assignment preview + the merge-offload decision
        p = plan(100_000, 10, k=10, devices=[object()] * 4, mutable=True)
        assert p.engine == "dynamic"
        assert p.n_shards == 4 and p.n_devices == 4
        assert p.merge_async
        assert not any("single-device" in r for r in p.reasons)
        placement = [r for r in p.reasons if "mutable multi-device" in r]
        assert placement and "4 devices" in placement[0]
        assert "rung" in placement[0] and "->dev" in placement[0]
        assert "brute rungs pinned" in placement[0]
        assert any("background staging worker" in r for r in p.reasons)

    def test_mutable_single_device_fallback(self):
        # devices=1: placement and fan-out degenerate, and the plan says so
        p = plan(100_000, 10, k=10, devices=[object()], mutable=True)
        assert p.engine == "dynamic"
        assert p.n_shards == 1
        assert any("single-device" in r for r in p.reasons)
        assert not any("mutable multi-device" in r for r in p.reasons)

    def test_merge_async_pin_is_honored(self):
        p = plan(100_000, 10, k=10, devices=[object()] * 2, mutable=True,
                 merge_async=False)
        assert not p.merge_async
        assert any("inline" in r and "merge_async=False" in r
                   for r in p.reasons)
        # default (None) resolves to background merges
        p2 = plan(100_000, 10, k=10, devices=[object()] * 2, mutable=True)
        assert p2.merge_async

    def test_dynamic_caps_declare_device_parallel_mutability(self):
        caps = available_engines()["dynamic"]
        assert caps.multi_device and caps.mutable
        assert caps.device_parallel_mutable
        # no immutable engine claims the composed capability
        for name, c in available_engines().items():
            if not c.mutable:
                assert not c.device_parallel_mutable, name

    def test_mutable_budget_shortfall_is_recorded(self):
        # an infeasible budget (below the 2-leaf streaming floor of the
        # largest rung even at int8) must set the structured over_budget
        # flag and say so in prose — never silently ignored
        p = plan(200_000, 10, k=10, devices=[object()], mutable=True,
                 memory_budget=100_000)
        assert p.engine == "dynamic"
        assert p.over_budget
        assert any("[over budget]" in r and "2-leaf streaming floor" in r
                   for r in p.reasons)

    def test_mutable_budget_shortfall_not_hidden_by_placement(self):
        # the largest rung is never split across devices, so more devices
        # must NOT shrink the per-device worst-case estimate below the
        # budget and silently drop the warning
        p = plan(200_000, 10, k=10, devices=[object()] * 4, mutable=True,
                 memory_budget=100_000)
        assert p.over_budget
        assert any("[over budget]" in r for r in p.reasons)

    def test_mutable_with_immutable_pin_rejected(self):
        with pytest.raises(ValueError, match="mutable=True"):
            plan(50_000, 8, devices=[object()], engine="chunked",
                 mutable=True)

    def test_facade_insert_delete_roundtrip(self):
        pts, q = _data(3000, 30, 6, seed=22)
        idx = KNNIndex.build(pts, spec=IndexSpec(mutable=True, k_hint=5))
        assert idx.engine_name == "dynamic"
        extra = _data(40, 1, 6, seed=23)[0]
        ids = idx.insert(extra)
        assert ids.tolist() == list(range(3000, 3040))
        assert idx.n == 3040
        res = idx.query(q, k=5)
        bd, _ = knn_brute(q, np.concatenate([pts, extra]), 5)
        np.testing.assert_allclose(res.dists, bd, rtol=1e-4, atol=1e-4)
        assert idx.delete(ids[:10]) == 10
        assert idx.n == 3030
        res = idx.query(q, k=5)
        bd, _ = knn_brute(q, np.concatenate([pts, extra[10:]]), 5)
        np.testing.assert_allclose(res.dists, bd, rtol=1e-4, atol=1e-4)

    def test_facade_insert_validates_dims(self):
        pts, _ = _data(500, 1, 6, seed=24)
        idx = KNNIndex.build(pts, spec=IndexSpec(mutable=True))
        with pytest.raises(ValueError, match="points must be"):
            idx.insert(np.zeros((3, 5), np.float32))


class TestPlanner:
    def test_memory_budget_drives_chunk_count(self):
        n, d = 200_000, 10
        slab = estimate_slab_bytes(n, d, height=plan(n, d).height)
        # budget below the slab => chunked with N > 1 and two buffers
        # fitting (precision pinned: otherwise the planner prefers
        # quantizing down to fit resident over chunk-streaming)
        budget = slab // 3
        p = plan(n, d, k=10, devices=[object()], memory_budget=budget,
                 precision="fp32")
        assert p.engine == "chunked"
        assert p.n_chunks > 1
        # resident estimate uses CEIL leaves-per-chunk (what the store
        # actually allocates) and must satisfy the budget
        assert p.resident_bytes <= budget
        assert any("chunk" in r for r in p.reasons)
        # generous budget => device-resident N=1
        p1 = plan(n, d, k=10, devices=[object()], memory_budget=slab * 2)
        assert (p1.engine, p1.n_chunks) == ("chunked", 1)
        assert p1.precision == "fp32"

    def test_device_count_drives_forest(self):
        p = plan(100_000, 10, k=10, devices=[object()] * 4)
        assert p.engine == "forest"
        assert p.n_shards == 4
        # uneven n cannot shard evenly -> paper-style query chunking
        p2 = plan(100_001, 10, k=10, devices=[object()] * 4)
        assert p2.engine == "sharded"

    def test_tiny_n_takes_brute(self):
        p = plan(1000, 8, k=5, devices=[object()])
        assert p.engine == "brute"
        # pinning a tree parameter opts out of the brute shortcut
        p2 = plan(1000, 8, k=5, devices=[object()], height=3)
        assert p2.engine != "brute"

    def test_small_query_batch_takes_brute(self):
        # m*n below the crossover: tree construction would dominate
        p = plan(50_000, 8, m=16, k=5, devices=[object()])
        assert p.engine == "brute"
        # same n with a big batch amortizes the build
        p2 = plan(50_000, 8, m=50_000, k=5, devices=[object()])
        assert p2.engine == "chunked"

    def test_multi_device_budget_falls_back_to_sharded_chunking(self):
        n, d = 100_000, 10
        h = plan(n, d, devices=[object()] * 4).height
        per_shard = estimate_slab_bytes(n, d, h) // 4
        # budget below the per-shard slab: forest's device-resident shards
        # cannot fit -> sharded replicas with chunk streaming (precision
        # pinned so the planner cannot quantize its way back under budget)
        p = plan(n, d, k=10, devices=[object()] * 4,
                 memory_budget=per_shard // 2, precision="fp32")
        assert p.engine == "sharded"
        assert p.n_chunks > 1
        assert any("budget" in r for r in p.reasons)

    def test_pinned_host_engine_honors_budget(self):
        n, d = 200_000, 10
        h = plan(n, d, devices=[object()]).height
        budget = estimate_slab_bytes(n, d, h) // 3
        p = plan(n, d, devices=[object()], engine="host",
                 memory_budget=budget, precision="fp32")
        assert p.n_chunks > 1
        assert p.resident_bytes <= budget

    def test_pinned_chunks_on_multi_device_routes_to_sharded(self):
        # forest shards are device-resident: an explicit out-of-core pin
        # must not be silently dropped
        p = plan(100_000, 10, k=10, devices=[object()] * 4, n_chunks=4)
        assert p.engine == "sharded"
        assert p.n_chunks == 4
        assert any("chunk" in r for r in p.reasons)

    def test_pinned_uneven_n_shards_falls_back_to_sharded(self):
        # the promised fallback must hold for caller-pinned shard counts
        # too, not just the device count
        p = plan(16384, 10, k=10, devices=[object()] * 4, n_shards=3)
        assert p.engine == "sharded"
        p2 = plan(16383, 10, k=10, devices=[object()] * 3, n_shards=3)
        assert p2.engine == "forest"  # 16383 = 3 * 5461 divides evenly

    def test_brute_shortcut_respects_budget(self):
        # small batch over a large set would take brute, but brute keeps the
        # whole padded reference set resident — budget forbids it
        p = plan(50_000, 8, m=16, k=5, devices=[object()],
                 memory_budget=500_000)
        assert p.engine == "chunked"
        assert p.resident_bytes <= 500_000

    def test_pinned_n_chunks_reason_is_honest(self):
        p = plan(50_000, 8, m=50_000, devices=[object()], n_chunks=8)
        assert p.n_chunks == 8
        assert any("pinned by caller" in r for r in p.reasons)
        assert not any("device-resident (N=1)" in r for r in p.reasons)

    def test_resident_bytes_single_source(self):
        # Plan.resident_bytes and the engine hook agree (one cost model)
        from repro.api import get_engine

        for eng in ("brute", "kdtree", "chunked", "forest", "ring"):
            p = plan(40_000, 10, devices=[object()] * 2, engine=eng)
            assert p.resident_bytes == get_engine(eng).resident_bytes(p)
        assert plan(40_000, 10, devices=[object()], engine="kdtree").resident_bytes == 0

    def test_height_clamped_so_leaves_hold_k(self):
        p = plan(5000, 8, k=64, devices=[object()], n_chunks=1)
        assert (5000 >> p.height) >= 64

    def test_buffer_size_follows_footnote8(self):
        p = plan(300_000, 10, devices=[object()])
        assert p.buffer_size == min(1 << (24 - p.height), 4096)
        assert p.fetch_m == 10 * p.buffer_size

    def test_explicit_engine_honored(self):
        p = plan(50_000, 10, devices=[object()] * 4, engine="ring")
        assert p.engine == "ring" and p.n_shards == 4

    def test_k_gt_n_rejected(self):
        with pytest.raises(ValueError, match="k="):
            plan(10, 4, k=11)


class TestCalibration:
    """Measured-cost planning: a Calibration turns rule-based decisions
    into calibrated ones, and every calibrated decision lands in reasons
    with the numbers it used."""

    def _cal(self, **kw):
        from repro.api import Calibration

        base = dict(h2d_gbps=10.0, h2d_latency_s=50e-6, round_s=5e-3,
                    engine_qps={"chunked": 2500.0, "host": 600.0},
                    source="test")
        base.update(kw)
        return Calibration(**base)

    def test_uncalibrated_plan_defaults(self):
        p = plan(50_000, 8, m=50_000, devices=[object()])
        assert not p.calibrated
        assert p.visit_policy == "pending_desc"
        assert p.starvation_deadline >= 1

    def test_calibrated_engine_choice_shows_numbers(self):
        p = plan(50_000, 8, m=50_000, devices=[object()],
                 calibration=self._cal())
        assert p.calibrated
        assert p.engine == "chunked"   # 2500 q/s beats 600 q/s
        assert any("calibrated engine choice" in r and "2500" in r
                   for r in p.reasons)

    def test_calibrated_choice_can_flip_engine(self):
        # if measurement says the host tier is faster, the planner follows
        # the measurement, not the rule
        p = plan(50_000, 8, m=50_000, devices=[object()],
                 calibration=self._cal(engine_qps={"chunked": 100.0,
                                                   "host": 900.0}))
        assert p.engine == "host"

    def test_calibrated_deadline_from_cost_ratio(self):
        # copy cost >> round cost => starved chunks wait longer (deadline
        # grows), capped at 16
        slow_copy = self._cal(h2d_gbps=0.001, round_s=1e-3)
        n, d = 200_000, 10
        h = plan(n, d, devices=[object()]).height
        budget = estimate_slab_bytes(n, d, h) // 3
        p = plan(n, d, k=10, devices=[object()], memory_budget=budget,
                 calibration=slow_copy)
        assert p.starvation_deadline == 16
        assert any("starvation deadline" in r for r in p.reasons)
        fast_copy = self._cal(h2d_gbps=1000.0, round_s=5e-3)
        p2 = plan(n, d, k=10, devices=[object()], memory_budget=budget,
                  calibration=fast_copy)
        assert p2.starvation_deadline == 1

    def test_calibrated_chunk_note_shows_copy_cost(self):
        n, d = 200_000, 10
        h = plan(n, d, devices=[object()]).height
        budget = estimate_slab_bytes(n, d, h) // 3
        p = plan(n, d, k=10, devices=[object()], memory_budget=budget,
                 calibration=self._cal(), precision="fp32")
        assert any("calibrated chunk copy" in r and "GB/s" in r
                   for r in p.reasons)

    def test_partial_calibration_is_harmless(self):
        from repro.api import Calibration

        p = plan(50_000, 8, m=50_000, devices=[object()],
                 calibration=Calibration(source="empty"))
        assert p.calibrated
        assert p.engine == "chunked"   # falls back to the rule
        assert p.starvation_deadline >= 1

    def test_load_roundtrip(self, tmp_path):
        import json

        from repro.api import Calibration

        (tmp_path / "BENCH_copy_cost.json").write_text(json.dumps(
            {"h2d_gbps": 12.5, "h2d_latency_s": 1e-5, "round_s": 4e-3}
        ))
        (tmp_path / "BENCH_engine.json").write_text(json.dumps(
            {"shape": {"m": 2000}, "chunked_s": 0.8, "host_s": 3.2,
             "chunked_qps": 2500.0}
        ))
        cal = Calibration.load(root=str(tmp_path))
        assert cal is not None
        assert cal.h2d_gbps == 12.5 and cal.round_s == 4e-3
        assert cal.engine_qps["chunked"] == 2500.0
        assert cal.engine_qps["host"] == pytest.approx(2000 / 3.2)
        assert "BENCH_copy_cost.json" in cal.source

    def test_load_missing_files_returns_none(self, tmp_path):
        from repro.api import Calibration

        assert Calibration.load(root=str(tmp_path / "nowhere")) is None

    def test_stale_calibration_warns_and_lands_in_reasons(self):
        # the old failure mode: load() silently served week-old numbers.
        # Now the age travels with the Calibration, plan() warns, and the
        # staleness is recorded next to the decisions that used it.
        cal = self._cal(age_s=10 * 86400.0)
        assert cal.stale
        with pytest.warns(UserWarning, match="days old"):
            p = plan(50_000, 8, m=50_000, devices=[object()],
                     calibration=cal)
        assert any("calibration stale" in r for r in p.reasons)
        assert p.calibrated   # stale numbers are still used, just audited

    def test_fresh_calibration_does_not_warn(self):
        import warnings as _warnings

        cal = self._cal(age_s=3600.0)
        assert not cal.stale
        with _warnings.catch_warnings():
            _warnings.simplefilter("error")
            p = plan(50_000, 8, m=50_000, devices=[object()],
                     calibration=cal)
        assert not any("stale" in r for r in p.reasons)

    def test_load_derives_age_from_file_mtime(self, tmp_path):
        import json
        import os
        import time

        from repro.api import CALIBRATION_STALE_S, Calibration

        cc = tmp_path / "BENCH_copy_cost.json"
        cc.write_text(json.dumps({"h2d_gbps": 10.0, "round_s": 1e-3}))
        old = time.time() - (CALIBRATION_STALE_S + 86400)
        os.utime(cc, (old, old))
        cal = Calibration.load(root=str(tmp_path))
        assert cal is not None and cal.age_s > CALIBRATION_STALE_S
        assert cal.stale
        # a fresh file is not stale
        os.utime(cc, None)
        assert not Calibration.load(root=str(tmp_path)).stale

    def test_load_reads_dynamic_bench(self, tmp_path):
        import json

        from repro.api import Calibration

        (tmp_path / "BENCH_dynamic.json").write_text(json.dumps(
            {"build_pps": 1e6, "crossover_batch": 4096}
        ))
        cal = Calibration.load(root=str(tmp_path))
        assert cal.build_pps == 1e6 and cal.dynamic_crossover == 4096
        assert "BENCH_dynamic.json" in cal.source
        # a measured crossover overrides the planner's model
        p = plan(50_000, 8, devices=[object()], mutable=True,
                 calibration=cal)
        assert p.crossover_batch == 4096
        assert any("measured rebuild-vs-merge crossover" in r
                   for r in p.reasons)

    def test_measured_null_crossover_is_not_conflated_with_unmeasured(
        self, tmp_path
    ):
        import json

        from repro.api import Calibration

        # dynamic_bench writes crossover_batch null when batch-dynamic won
        # at every measured size — the planner must honor that, not fall
        # back to the model and force flattening rebuilds
        (tmp_path / "BENCH_dynamic.json").write_text(json.dumps(
            {"build_pps": 1e6, "crossover_batch": None}
        ))
        cal = Calibration.load(root=str(tmp_path))
        assert cal.dynamic_measured and cal.dynamic_crossover is None
        p = plan(50_000, 8, devices=[object()], mutable=True,
                 calibration=cal)
        assert p.crossover_batch is None
        assert any("won at every measured batch size" in r
                   for r in p.reasons)

    def test_spec_carries_calibration_through_facade(self):
        pts, q = _data(6000, 64, 6, seed=9)
        idx = KNNIndex.build(
            pts, spec=IndexSpec(engine="chunked", height=4,
                                calibration=self._cal())
        )
        assert idx.plan.calibrated
        dists, ids = idx.query(q, k=5)
        bd, _ = knn_brute(q, pts, 5)
        np.testing.assert_allclose(dists, bd, rtol=1e-4, atol=1e-4)


class TestCalibrationRefresh:
    """``calibration="refresh"``: instead of warning about week-old bench
    numbers, plan() re-runs the cheap inline H2D probe and plans from the
    fresh fit — the measurement lands in reasons like any other."""

    def test_refresh_remeasures_h2d_over_base(self):
        from repro.api import Calibration

        base = Calibration(h2d_gbps=0.001, round_s=5e-3,
                           engine_qps={"chunked": 2500.0},
                           age_s=30 * 86400.0, source="old-bench")
        cal = Calibration.refresh(base)
        assert cal.h2d_gbps > 0.001 and cal.h2d_latency_s >= 0.0
        assert not cal.stale and cal.age_s == 0.0
        # slower fields carry over unmodified; provenance is appended
        assert cal.round_s == 5e-3
        assert cal.engine_qps == {"chunked": 2500.0}
        assert cal.source == "old-bench+inline-refresh"

    def test_refresh_from_nothing(self):
        from repro.api import Calibration

        cal = Calibration.refresh()
        assert cal.h2d_gbps and cal.h2d_gbps > 0
        assert cal.source == "inline-refresh"

    def test_plan_accepts_refresh_string(self):
        import warnings as _warnings

        # must not raise, must not warn about staleness (the point of the
        # escape hatch) — whether the repo's committed bench files are
        # fresh or stale, "refresh" always yields a usable calibration
        with _warnings.catch_warnings():
            _warnings.simplefilter("error", UserWarning)
            p = plan(50_000, 8, m=50_000, devices=[object()],
                     calibration="refresh")
        assert p.calibrated

    def test_other_strings_rejected(self):
        with pytest.raises(ValueError, match="refresh"):
            plan(50_000, 8, devices=[object()], calibration="reload")

    def test_stale_load_triggers_inline_probe(self, tmp_path, monkeypatch):
        import json
        import os as _os
        import time as _time

        from repro.api import CALIBRATION_STALE_S
        from repro.api import planner as planner_mod

        cc = tmp_path / "BENCH_copy_cost.json"
        cc.write_text(json.dumps({"h2d_gbps": 10.0, "round_s": 1e-3}))
        old = _time.time() - (CALIBRATION_STALE_S + 86400)
        _os.utime(cc, (old, old))
        # point plan()'s internal Calibration.load at the stale tmp root
        orig_load = planner_mod.Calibration.load.__func__
        monkeypatch.setattr(
            planner_mod.Calibration, "load",
            classmethod(lambda cls, root=None: orig_load(cls, str(tmp_path))),
        )
        p = plan(50_000, 8, m=50_000, devices=[object()],
                 calibration="refresh")
        assert any("calibration auto-refresh" in r for r in p.reasons)
        # the inline probe only re-measures the fast H2D fields; the slow
        # ones (round cost, engine q/s) still carry the old timestamps, so
        # the plan must say so instead of pretending refresh fixed them
        assert any("calibration stale: slow fields" in r for r in p.reasons)
        assert not any("calibration stale" in r and "slow fields" not in r
                       for r in p.reasons)


class TestKNNIndexFacade:
    def test_auto_plan_small_is_brute_and_exact(self):
        pts, q = _data(1500, 40, 6, seed=5)
        idx = KNNIndex.build(pts)
        assert idx.engine_name == "brute"
        res = idx.query(q, k=4)
        bd, bi = knn_brute(q, pts, 4)
        np.testing.assert_allclose(res.dists, bd, rtol=1e-4, atol=1e-4)

    def test_auto_plan_memory_budget_chunked_and_exact(self):
        pts, q = _data(30_000, 100, 8, seed=6)
        full = KNNIndex.build(pts)
        budget = full.resident_bytes() // 4
        idx = KNNIndex.build(pts, memory_budget=budget)
        assert idx.engine_name == "chunked"
        assert idx.plan.n_chunks > 1
        assert idx.resident_bytes() < full.resident_bytes()
        res = idx.query(q, k=10)
        bd, _ = knn_brute(q, pts, 10)
        np.testing.assert_allclose(res.dists, bd, rtol=1e-4, atol=1e-4)

    def test_sharded_stats_exclude_idle_devices(self):
        # m < n_devices leaves some engines idle; their stale .stats must
        # not leak into the batch's aggregate (repeat the one in-process
        # device to get a 3-engine state)
        import jax

        from repro.distributed.sharded import MultiDeviceTrees

        pts, q = _data(3000, 40, 6, seed=13)
        eng = get_engine("sharded")
        state = MultiDeviceTrees(pts, height=3, devices=jax.devices() * 3)
        _, _, s_big = eng.query(state, q, 4)        # all 3 engines run
        _, _, s_tiny = eng.query(state, q[:1], 4)   # only engine 0 runs
        assert state.active == [0]
        assert s_tiny.points_scanned < s_big.points_scanned

    def test_chunked_resident_estimate_matches_store(self):
        # uneven leaves-per-chunk (16 leaves / 7 chunks -> ceil = 3): the
        # plan's estimate must equal what ChunkedLeafStore really allocates
        pts, _ = _data(1600, 1, 8, seed=20)
        idx = KNNIndex.build(pts, engine="chunked", height=4, n_chunks=7)
        assert idx.plan.resident_bytes == idx.resident_bytes()

    def test_stats_are_per_call_values(self):
        pts, q = _data(9000, 200, 8, seed=7)
        idx = KNNIndex.build(pts, engine="chunked", height=4)
        r1 = idx.query(q, k=5)
        r2 = idx.query(q[:10], k=5)
        assert isinstance(r1.stats, SearchStats)
        assert r1.stats is not r2.stats
        assert r1.stats.queries_advanced != r2.stats.queries_advanced
        with pytest.raises(dataclasses_frozen_error()):
            r1.stats.iterations = 99  # immutable
        # .stats mirrors the LAST call only
        assert idx.stats == r2.stats

    def test_result_fields(self):
        pts, q = _data(800, 12, 5, seed=8)
        res = KNNIndex.build(pts).query(q, k=3)
        assert isinstance(res, QueryResult)
        assert res.k == 3
        assert res.dists.shape == (12, 3)
        assert res.idx.shape == (12, 3)
        assert len(res) == 2 and res[0] is res.dists and res[1] is res.idx

    def test_dim_mismatch_rejected(self):
        pts, _ = _data(600, 1, 6, seed=9)
        idx = KNNIndex.build(pts)
        with pytest.raises(ValueError, match="queries must be"):
            idx.query(np.zeros((4, 5), np.float32), k=2)

    def test_describe_mentions_engine_and_reasons(self):
        pts, _ = _data(600, 1, 6, seed=10)
        idx = KNNIndex.build(pts)
        text = idx.describe()
        assert "engine=brute" in text
        assert "brute scan" in text


def dataclasses_frozen_error():
    import dataclasses

    return dataclasses.FrozenInstanceError


class TestBufferKDTreeBackCompat:
    def test_stats_property_reflects_last_query(self):
        from repro.core import BufferKDTree

        pts, q = _data(4000, 120, 8, seed=11)
        idx = BufferKDTree(pts, height=4)
        d1, _ = idx.query(q, k=5)
        s1 = idx.stats
        idx.query(q[:7], k=5)
        s2 = idx.stats
        assert s1.queries_advanced > s2.queries_advanced
        assert s1.points_scanned > 0
        # the old mutate-in-place field is gone; stats are frozen values
        with pytest.raises(dataclasses_frozen_error()):
            idx.stats.iterations = 0

    def test_host_engine_plan_shapes_tracked_per_call(self):
        from repro.core import BufferKDTree

        pts, q = _data(4000, 120, 8, seed=12)
        idx = BufferKDTree(pts, height=4, engine="host")
        idx.query(q, k=5)
        assert idx.stats.plan_shapes >= 1


class TestLeafBuffersVectorized:
    def test_fill_counts_and_flush_rule(self):
        from repro.core.buffers import LeafBuffers

        b = LeafBuffers(n_leaves=8, capacity=16)
        assert not b.should_flush()
        b.insert(np.array([1, 1, 1, 2], np.int32), np.arange(4, dtype=np.int32))
        assert b.total == 4
        assert b.max_fill == 3
        assert not b.should_flush()            # 3 < B/2 = 8
        b.insert(np.full((5,), 1, np.int32), np.arange(5, dtype=np.int32))
        assert b.max_fill == 8
        assert b.should_flush()                # 8 >= B/2
        leaf, query = b.drain()
        assert leaf.shape == (9,)
        assert b.total == 0 and b.max_fill == 0
        # drain resets fill counts fully
        b.insert(np.array([7], np.int32), np.array([0], np.int32))
        assert b.max_fill == 1


@pytest.mark.slow
def test_multi_device_auto_plan_selects_forest_and_is_exact():
    """Acceptance: >1 visible device => auto-planned forest, exact results.

    Runs in a subprocess with 4 forced host devices (the main pytest
    process must keep the real 1-CPU device view; see test_distributed).
    """
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        import jax
        jax.config.update("jax_cpu_enable_async_dispatch", False)
        from repro.api import KNNIndex, knn_brute
        rng = np.random.default_rng(0)
        n, d, m, k = 16384, 10, 256, 10
        pts = rng.normal(size=(n, d)).astype(np.float32)
        q = rng.normal(size=(m, d)).astype(np.float32)
        idx = KNNIndex.build(pts)
        assert idx.engine_name == "forest", idx.describe()
        assert idx.plan.n_shards == 4
        dd, di = idx.query(q, k=k)
        bd, bi = knn_brute(q, pts, k)
        assert np.allclose(dd, bd, rtol=1e-4, atol=1e-4)
        assert (di == bi).mean() > 0.999
        print("FOREST_AUTOPLAN_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=1800,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    assert "FOREST_AUTOPLAN_OK" in out.stdout


# -- multi-op front door ------------------------------------------------
#
# The op sweep below is AUTO-DISCOVERED from the registry, exactly like
# the kNN parity suite: every op an engine declares in caps.ops must be
# exact vs the numpy/brute oracle on the same edge shapes.  Parity data
# is an integer lattice (squared distances exact in fp32) with radii /
# edges whose squares are non-integers, so radius and pair_count compare
# bit-exact — no fp32-vs-f64 bin-boundary straddle.

DUAL_OPS = ("radius", "kde", "pair_count")
OP_PAIRS = sorted(
    (op, eng) for op in DUAL_OPS for eng in available_engines(op=op)
)
NON_DECLARING = sorted(
    eng for eng in ALL_ENGINES
    if not any(op in get_engine(eng).caps.ops for op in DUAL_OPS)
)


def _lattice_data(n, m, d, seed=0):
    rng = np.random.default_rng(seed)
    span = max(3, int(np.sqrt(300 / d)))
    pts = rng.integers(0, span, size=(n, d)).astype(np.float32)
    q = rng.integers(0, span, size=(m, d)).astype(np.float32)
    return pts, q


# squared values are non-integers: no lattice distance sits on an edge
_EDGES = np.sqrt(np.array([0.5, 3.5, 7.5, 16.5, 32.5, 64.5, 144.5]))


def _csr_rows_equal(ip_a, ix_a, ip_b, ix_b):
    assert np.array_equal(ip_a, ip_b)
    for i in range(len(ip_a) - 1):
        assert set(ix_a[ip_a[i]:ip_a[i + 1]].tolist()) == set(
            ix_b[ip_b[i]:ip_b[i + 1]].tolist()
        ), f"row {i}"


class TestOpParity:
    @pytest.mark.parametrize("op,engine", OP_PAIRS,
                             ids=[f"{o}-{e}" for o, e in OP_PAIRS])
    @pytest.mark.parametrize("n,m,d,k,height", PARITY_SHAPES)
    def test_declared_op_exact_vs_oracle(self, op, engine, n, m, d, k, height):
        from repro.core.dualtree import (
            kde_brute, pair_count_brute, radius_brute,
        )

        pts, q = _lattice_data(n, m, d, seed=hash((op, n, m, d)) % 1000)
        idx = KNNIndex.build(
            pts, spec=IndexSpec(engine=engine, op=op, height=height,
                                m_hint=m)
        )
        if op == "radius":
            r = float(np.sqrt(1.5 * d + 0.5))
            res = idx.radius(q, r)
            assert isinstance(res, RadiusResult)
            bi, bj, _ = radius_brute(q, pts, r)
            _csr_rows_equal(res.indptr, res.indices, bi, bj)
            assert res.engine == engine and res.r == r
        elif op == "kde":
            h, rtol, atol = float(np.sqrt(d)), 1e-2, 1e-9
            res = idx.kde(q, h, rtol=rtol, atol=atol)
            assert isinstance(res, StatResult) and res.op == "kde"
            exact = kde_brute(q, pts, h).astype(np.float64)
            bound = rtol * exact + atol + 1e-5 * np.maximum(exact, 1.0)
            assert np.all(
                np.abs(res.values.astype(np.float64) - exact) <= bound
            )
        else:
            res = idx.pair_count(_EDGES)
            assert isinstance(res, StatResult) and res.op == "pair_count"
            ref = pair_count_brute(pts, _EDGES)
            assert np.array_equal(res.values, ref)
            assert res.values.sum() > 0  # non-degenerate histogram
            assert res.error_bound == 0.0
        assert isinstance(res.stats, SearchStats)


class TestOpCapsContract:
    """The other half of the sweep: engines declaring ONLY knn must raise
    the typed ``OpUnsupported`` from every multi-op entry point (never
    compute silently), mirroring the Mutability/Streaming contracts."""

    def test_known_ops_closed_set(self):
        assert KNOWN_OPS == {"knn", "radius", "kde", "pair_count"}
        for name, caps in available_engines().items():
            assert caps.ops <= KNOWN_OPS, name
            assert "knn" in caps.ops, name

    def test_dualtree_engines_declare_all_ops(self):
        for name in ("brute", "host", "chunked", "streaming"):
            assert set(DUAL_OPS) <= get_engine(name).caps.ops, name

    def test_available_engines_op_filter(self):
        for op in DUAL_OPS:
            decl = available_engines(op=op)
            assert decl and all(op in c.ops for c in decl.values())
        assert set(available_engines(op="knn")) == set(ALL_ENGINES)
        with pytest.raises(ValueError, match="unknown op"):
            available_engines(op="warp")

    @pytest.mark.parametrize("engine", NON_DECLARING)
    def test_non_declaring_engines_raise_typed_error(self, engine):
        pts, q = _lattice_data(700, 16, 4, seed=22)
        idx = KNNIndex.build(pts, spec=IndexSpec(engine=engine, height=2))
        with pytest.raises(OpUnsupported, match="radius"):
            idx.radius(q, 1.0)
        with pytest.raises(OpUnsupported, match="kde"):
            idx.kde(q, 1.0)
        with pytest.raises(OpUnsupported, match="pair_count"):
            idx.pair_count(np.array([0.5, 1.5]))
        with pytest.raises(OpUnsupported):
            idx.warm(m=8, ops=("radius",))
        assert isinstance(OpUnsupported("x"), TypeError)

    def test_error_names_declaring_engines(self):
        pts, _ = _lattice_data(700, 4, 4, seed=23)
        idx = KNNIndex.build(pts, spec=IndexSpec(engine="jit", height=2))
        with pytest.raises(OpUnsupported, match="chunked"):
            idx.pair_count(np.array([0.5, 1.5]))


class TestPlannerOpRules:
    def test_unknown_op_rejected(self):
        with pytest.raises(ValueError, match="unknown op"):
            plan(5000, 8, op="warp")
        with pytest.raises(ValueError, match="unknown op"):
            KNNIndex.build(np.zeros((64, 3), np.float32),
                           spec=IndexSpec(op="warp"))

    def test_declared_op_lands_in_reasons(self):
        p = plan(5000, 8, m=300, op="radius")
        assert "radius" in get_engine(p.engine).caps.ops
        assert any("op='radius'" in r for r in p.reasons)

    def test_pinned_engine_lacking_op_raises(self):
        with pytest.raises(ValueError, match="does not declare"):
            plan(5000, 8, engine="forest", op="kde")

    def test_mutable_plus_dual_op_is_a_contradiction(self):
        with pytest.raises(ValueError, match="mutable"):
            plan(5000, 8, mutable=True, op="pair_count")

    def test_auto_choice_reroutes_to_declaring_engine(self):
        class FakeDev:
            platform = "cpu"

        p = plan(200_000, 8, m=1000, op="pair_count",
                 devices=tuple(FakeDev() for _ in range(4)))
        assert "pair_count" in get_engine(p.engine).caps.ops
        assert any("rerouted" in r for r in p.reasons)

    def test_spec_op_rides_the_facade(self):
        pts, q = _lattice_data(900, 32, 3, seed=24)
        idx = KNNIndex.build(pts, spec=IndexSpec(op="radius", height=3))
        assert "radius" in idx._engine.caps.ops
        assert idx.spec.op == "radius"
        res = idx.radius(q, 1.5)
        assert isinstance(res, RadiusResult)
        # the knn path stays byte-compatible on the same index
        dists, ids = idx.query(q, k=3)
        bd, _ = knn_brute(q, pts, 3)
        np.testing.assert_allclose(dists, bd, rtol=1e-4, atol=1e-4)


class TestOpResults:
    def test_radius_result_unpacks_as_csr_triple(self):
        pts, q = _lattice_data(800, 24, 3, seed=25)
        res = KNNIndex.build(pts, spec=IndexSpec(op="radius")).radius(q, 2.3)
        indptr, indices, dists = res
        assert len(res) == 3
        assert res[0] is indptr and res[1] is indices and res[2] is dists
        assert indptr.shape == (25,) and indptr[0] == 0
        assert indptr[-1] == len(indices) == len(dists)
        with pytest.raises(dataclasses_frozen_error()):
            res.r = 9.0

    def test_stat_result_unpacks_as_value_error_pair(self):
        pts, q = _lattice_data(800, 24, 3, seed=26)
        idx = KNNIndex.build(pts, spec=IndexSpec(op="kde"))
        res = idx.kde(q, 1.0)
        values, err = res
        assert len(res) == 2 and res[0] is values
        assert values.shape == (24,) and err >= 0.0
        hist_res = idx.pair_count(np.array([0.5, 1.5, 2.5]))
        assert hist_res.values.dtype == np.int64
        assert hist_res.op == "pair_count"
        with pytest.raises(dataclasses_frozen_error()):
            hist_res.op = "other"

    def test_op_stats_are_per_call_values(self):
        pts, q = _lattice_data(1200, 64, 3, seed=27)
        idx = KNNIndex.build(pts, spec=IndexSpec(engine="chunked", op="radius",
                                                 height=3))
        r1 = idx.radius(q, 2.3)
        r2 = idx.radius(q[:4], 2.3)
        assert r1.stats is not r2.stats
        assert idx.stats == r2.stats  # facade mirrors the LAST call
        with pytest.raises(dataclasses_frozen_error()):
            r1.stats.flushes = 5

    def test_arg_validation(self):
        pts, q = _lattice_data(600, 8, 3, seed=28)
        idx = KNNIndex.build(pts, spec=IndexSpec(op="radius"))
        with pytest.raises(ValueError, match="r >= 0"):
            idx.radius(q, -1.0)
        with pytest.raises(ValueError, match="bandwidth"):
            idx.kde(q, 0.0)
        with pytest.raises(ValueError, match="queries must be"):
            idx.radius(np.zeros((4, 9), np.float32), 1.0)
        with pytest.raises(ValueError):
            idx.pair_count(np.array([2.0, 1.0]))


class TestWarmPerOp:
    def test_warm_ops_then_new_operands_zero_compiles(self):
        pts, q = _lattice_data(2000, 150, 3, seed=29)
        idx = KNNIndex.build(pts, spec=IndexSpec(engine="chunked", op="radius",
                                                 height=3, m_hint=150))
        idx.warm(m=150, ops=DUAL_OPS, n_edges=len(_EDGES))
        before = dualtree_cache_size()
        idx.radius(q, 1.7)
        idx.radius(q, 3.3)
        idx.kde(q, 0.9)
        idx.pair_count(_EDGES)
        idx.pair_count(_EDGES * 1.5)
        assert dualtree_cache_size() == before

    def test_warm_defaults_to_spec_op(self):
        pts, _ = _lattice_data(900, 8, 3, seed=30)
        idx = KNNIndex.build(pts, spec=IndexSpec(engine="host", op="kde",
                                                 height=3))
        idx.warm(m=64)  # warms the spec's op without error
        with pytest.raises(ValueError, match="unknown op"):
            idx.warm(m=8, ops=("warp",))

    def test_knn_warm_signature_back_compat(self):
        pts, _ = _lattice_data(1200, 8, 3, seed=31)
        idx = KNNIndex.build(pts, spec=IndexSpec(engine="chunked", height=3))
        idx.warm(128, 5)  # positional (m, k), op defaults to spec's "knn"


class TestDeprecatedCacheSizeAlias:
    def test_old_name_warns_and_aliases_new(self):
        import warnings

        import repro.api as api

        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            old = api.chunk_round_cache_size
        assert old is api.knn_round_cache_size
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
        assert "chunk_round_cache_size" in api.__all__  # one more release

    def test_from_import_still_works(self):
        import warnings

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from repro.api import chunk_round_cache_size
        assert callable(chunk_round_cache_size)

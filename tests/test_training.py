"""Training substrate: optimizer math, schedules, accumulation, learning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import TokenPipeline
from repro.models.model import LanguageModel
from repro.training.optimizer import (
    Hyper, adamw_init, adamw_update, global_norm, lr_schedule,
)
from repro.training.step import build_train_step


class TestAdamW:
    def test_matches_numpy_reference(self):
        h = Hyper(lr=0.1, warmup_steps=0, total_steps=10**9, b1=0.9, b2=0.99,
                  eps=1e-8, weight_decay=0.01, clip_norm=1e9)
        p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
        g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
        st = adamw_init(p)
        p2, st2, _ = adamw_update(g, st, p, jnp.int32(0), h)
        # numpy AdamW, one step
        m = 0.1 * np.asarray(g["w"])
        v = 0.01 * np.asarray(g["w"]) ** 2
        mh = m / (1 - 0.9)
        vh = v / (1 - 0.99)
        lr = float(lr_schedule(jnp.int32(0), h))
        ref = np.asarray(p["w"]) - lr * (
            mh / (np.sqrt(vh) + 1e-8) + 0.01 * np.asarray(p["w"])
        )
        np.testing.assert_allclose(np.asarray(p2["w"]), ref, rtol=1e-5)

    def test_clipping(self):
        h = Hyper(lr=1.0, warmup_steps=0, clip_norm=0.5, weight_decay=0.0)
        p = {"w": jnp.zeros((4,))}
        g = {"w": jnp.full((4,), 100.0)}
        st = adamw_init(p)
        _, _, m = adamw_update(g, st, p, jnp.int32(0), h)
        assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-4)

    def test_master_fp32_update(self):
        h = Hyper(lr=0.1, warmup_steps=0, weight_decay=0.0, clip_norm=1e9)
        p = {"w": jnp.asarray([1.0], jnp.bfloat16)}
        st = adamw_init(p, master_fp32=True)
        assert st["master"]["w"].dtype == jnp.float32
        g = {"w": jnp.asarray([0.5], jnp.bfloat16)}
        p2, st2, _ = adamw_update(g, st, p, jnp.int32(0), h)
        assert p2["w"].dtype == jnp.bfloat16
        # master moved in fp32
        assert float(st2["master"]["w"][0]) != 1.0

    def test_lr_schedule_shape(self):
        h = Hyper(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        lrs = [float(lr_schedule(jnp.int32(t), h)) for t in (0, 5, 10, 55, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0, rel=1e-3)
        assert 0.1 < lrs[3] < 1.0
        assert lrs[4] == pytest.approx(0.1, rel=1e-2)


class TestTrainStep:
    def test_loss_falls_on_markov_data(self):
        cfg = get_config("qwen15_0_5b", smoke=True)
        lm = LanguageModel(cfg)
        params, _ = lm.init(jax.random.key(0))
        opt = adamw_init(params)
        step = jax.jit(build_train_step(
            lm, Hyper(lr=1e-2, warmup_steps=5, total_steps=50)))
        pipe = TokenPipeline(cfg.vocab_size, 32, 8, seed=1)
        losses = []
        p, o = params, opt
        for t in range(30):
            b = {k: jnp.asarray(v) for k, v in pipe.global_batch_at(t).items()}
            p, o, m = step(p, o, b, jnp.int32(t))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.2

    def test_grad_accum_equivalent(self):
        """ga=2 with the same total batch ~ ga=1 (strided split; loss metric
        averages, update identical up to fp noise)."""
        cfg = get_config("qwen15_0_5b", smoke=True).replace(
            dtype="float32", param_dtype="float32")
        lm = LanguageModel(cfg)
        params, _ = lm.init(jax.random.key(0))
        opt = adamw_init(params)
        pipe = TokenPipeline(cfg.vocab_size, 16, 8, seed=2)
        b = {k: jnp.asarray(v) for k, v in pipe.global_batch_at(0).items()}
        h1 = Hyper(lr=1e-3, warmup_steps=0, total_steps=10)
        h2 = Hyper(lr=1e-3, warmup_steps=0, total_steps=10, grad_accum=2)
        p1, _, m1 = jax.jit(build_train_step(lm, h1))(params, opt, b, jnp.int32(0))
        p2, _, m2 = jax.jit(build_train_step(lm, h2))(params, opt, b, jnp.int32(0))
        for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=1e-4, atol=1e-5)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)

    def test_unrolled_accum_matches_scan(self):
        cfg = get_config("qwen15_0_5b", smoke=True).replace(
            dtype="float32", param_dtype="float32")
        lm = LanguageModel(cfg)
        params, _ = lm.init(jax.random.key(0))
        opt = adamw_init(params)
        pipe = TokenPipeline(cfg.vocab_size, 16, 8, seed=3)
        b = {k: jnp.asarray(v) for k, v in pipe.global_batch_at(0).items()}
        hs = Hyper(lr=1e-3, warmup_steps=0, grad_accum=4)
        hu = Hyper(lr=1e-3, warmup_steps=0, grad_accum=4, unroll_accum=True)
        ps, _, _ = jax.jit(build_train_step(lm, hs))(params, opt, b, jnp.int32(0))
        pu, _, _ = jax.jit(build_train_step(lm, hu))(params, opt, b, jnp.int32(0))
        for a, c in zip(jax.tree.leaves(ps), jax.tree.leaves(pu)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=1e-5, atol=1e-6)

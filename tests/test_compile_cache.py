"""Persistent compilation cache: warm restarts skip XLA compiles.

``IndexSpec(compile_cache_dir=...)`` (and ``KNNIndex.load(...,
compile_cache_dir=...)``) wire jax's persistent compilation cache into the
index lifecycle, with hit/miss accounting surfaced through ``Plan.reasons``
— the same auditability contract as every other planner decision.

The cache is PROCESS-GLOBAL jax state, so the cold-start/warm-restart
lifecycle runs in subprocesses: run 1 populates a shared cache dir (cold
start, warm() reports a miss), run 2 is the simulated restart (warm start,
warm() reports a hit, entry count stable).  In-process tests only cover
the no-cache default and the spec plumbing.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.api import IndexSpec, KNNIndex

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

# Build + warm against a shared cache dir, print reason lines + entry count.
_LIFECYCLE = textwrap.dedent("""
    import glob, json, os, sys
    import numpy as np
    import jax
    jax.config.update("jax_cpu_enable_async_dispatch", False)
    from repro.api import IndexSpec, KNNIndex

    cache_dir = sys.argv[1]
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(3000, 8)).astype(np.float32)
    idx = KNNIndex.build(pts, spec=IndexSpec(
        engine="streaming", height=3, k_hint=5,
        compile_cache_dir=cache_dir,
    ))
    idx.warm(64, 5)
    q = rng.normal(size=(64, 8)).astype(np.float32)
    idx.query(q, k=5)
    print(json.dumps({
        "reasons": list(idx.plan.reasons),
        "entries": len(glob.glob(os.path.join(cache_dir, "*-cache"))),
    }))
""")


def _lifecycle_run(cache_dir: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", _LIFECYCLE, cache_dir],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_cold_then_warm_restart(tmp_path):
    cache_dir = str(tmp_path / "xla-cache")

    cold = _lifecycle_run(cache_dir)
    cache_line = [r for r in cold["reasons"] if "compile cache at" in r]
    assert cache_line and "cold start" in cache_line[0]
    warm_line = [r for r in cold["reasons"] if "for warm(" in r]
    assert warm_line and "miss: compiled" in warm_line[0]
    assert cold["entries"] > 0, "no cache entries persisted to disk"

    # simulated restart: fresh process, same cache dir => compiles are
    # served from disk and the entry count does not grow
    warm = _lifecycle_run(cache_dir)
    cache_line = [r for r in warm["reasons"] if "compile cache at" in r]
    assert cache_line and "warm start" in cache_line[0]
    assert f"{cold['entries']} executable(s) on disk" in cache_line[0]
    warm_line = [r for r in warm["reasons"] if "for warm(" in r]
    assert warm_line and "hit: served from disk" in warm_line[0]
    assert warm["entries"] == cold["entries"]


def test_no_cache_dir_means_no_cache_reasons():
    pts = np.random.default_rng(1).normal(size=(600, 6)).astype(np.float32)
    idx = KNNIndex.build(pts, spec=IndexSpec(engine="chunked", height=2))
    assert not any("compile cache" in r for r in idx.plan.reasons)


def test_spec_field_survives_replace_but_not_manifest():
    spec = IndexSpec(compile_cache_dir="/tmp/x")
    assert spec.replace(k_hint=7).compile_cache_dir == "/tmp/x"
    assert IndexSpec().compile_cache_dir is None
    # host-local path: must NOT leak into the persisted snapshot manifest
    # (cache dirs belong to the saving host, like persist_dir)
    from repro.api.index import _SPEC_MANIFEST_FIELDS
    assert "compile_cache_dir" not in _SPEC_MANIFEST_FIELDS

"""Chunk-resident bulk-synchronous engine: plan boundaries, exact parity
with the host k-d tree reference across chunk counts, and the recompile-free
guarantee (one compiled round per configuration, independent of flush
sizes)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BufferKDTree, build_top_tree, knn_host_kdtree
from repro.core.buffers import build_work_plan
from repro.core.chunked import ChunkedLeafStore
from repro.core.chunked_jit import chunk_round_cache_size
from repro.core.jitsearch import _build_plan
from repro.core.lazysearch import PLAN_LADDER, _plan_pad


def _data(n, m, d, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=(m, d)).astype(np.float32))


class TestBuildPlanBoundary:
    def _units_of(self, leaf, tq, n_leaves):
        ul, uq, nu = _build_plan(jnp.asarray(leaf, jnp.int32), tq, n_leaves)
        return np.asarray(ul), np.asarray(uq), int(nu)

    def _check_complete(self, leaf, tq, n_leaves):
        """Every live query appears exactly once, in a unit of its leaf."""
        ul, uq, nu = self._units_of(leaf, tq, n_leaves)
        w_max = -(-len(leaf) // tq) + n_leaves
        assert nu <= w_max
        # numpy reference plan: same number of units
        live = leaf >= 0
        ref = build_work_plan(leaf[live], np.nonzero(live)[0], tq)
        assert nu == ref.n_units
        seen = uq[:nu][uq[:nu] >= 0]
        assert sorted(seen.tolist()) == np.nonzero(live)[0].tolist()
        for u in range(nu):
            qs = uq[u][uq[u] >= 0]
            assert (leaf[qs] == ul[u]).all()
        # everything past the occupied prefix is padding
        assert (uq[nu:] == -1).all()

    def test_densest_packing_hits_w_max_region(self):
        """tq+1 queries per leaf = 2 units per leaf, the worst padding case:
        unit count must reach 2*n_leaves and still lose no query."""
        tq, n_leaves = 4, 8
        leaf = np.repeat(np.arange(n_leaves), tq + 1).astype(np.int32)
        ul, uq, nu = self._units_of(leaf, tq, n_leaves)
        assert nu == 2 * n_leaves
        assert nu <= -(-len(leaf) // tq) + n_leaves  # the W_max bound
        self._check_complete(leaf, tq, n_leaves)

    def test_single_query_per_leaf(self):
        """One query per leaf: n_leaves units, maximum slot padding."""
        tq, n_leaves = 8, 16
        leaf = np.arange(n_leaves).astype(np.int32)
        self._check_complete(leaf, tq, n_leaves)

    def test_retired_queries_go_to_dump(self):
        tq, n_leaves = 4, 4
        leaf = np.array([2, -1, 0, -1, 2, 2, 1, -1], np.int32)
        self._check_complete(leaf, tq, n_leaves)

    def test_all_retired(self):
        ul, uq, nu = self._units_of(np.full((6,), -1, np.int32), 4, 4)
        assert nu == 0
        assert (uq == -1).all()

    def test_plan_ladder_monotone_and_bounded(self):
        assert all(_plan_pad(w) >= w for w in range(1, 2000, 7))
        # the ladder is FIXED: only len(PLAN_LADDER) distinct pads below max
        pads = {_plan_pad(w) for w in range(1, PLAN_LADDER[-1] + 1, 13)}
        assert pads <= set(PLAN_LADDER)


class TestChunkedParity:
    @pytest.mark.parametrize("n_chunks", [1, 2, 3, 7])
    def test_exact_vs_host_kdtree(self, n_chunks):
        """The chunked engine must be EXACT (same rescored distances, same
        indices) vs the classic host k-d tree for every chunk count."""
        pts, q = _data(6000, 400, 6, seed=11)
        idx = BufferKDTree(pts, height=5, n_chunks=n_chunks, tile_q=32)
        dd, di = idx.query(q, k=9)
        hd, hi = knn_host_kdtree(q, idx.tree, 9)
        np.testing.assert_allclose(dd, hd, rtol=1e-6, atol=1e-7)
        np.testing.assert_array_equal(di, hi)

    @pytest.mark.parametrize("n_chunks", [1, 3])
    def test_chunked_engine_matches_host_engine(self, n_chunks):
        """Both engine tiers answer identically on the same tree."""
        pts, q = _data(3000, 200, 5, seed=7)
        fast = BufferKDTree(pts, height=4, n_chunks=n_chunks, tile_q=32)
        slow = BufferKDTree(pts, height=4, n_chunks=n_chunks, tile_q=32,
                            engine="host")
        fd, fi = fast.query(q, k=5)
        sd, si = slow.query(q, k=5)
        np.testing.assert_allclose(fd, sd, rtol=1e-6, atol=1e-7)
        np.testing.assert_array_equal(fi, si)

    def test_k_edges_and_duplicates(self):
        rng = np.random.default_rng(3)
        base = rng.normal(size=(64, 4)).astype(np.float32)
        pts = np.concatenate([base] * 3)
        idx = BufferKDTree(pts, height=3, n_chunks=2, tile_q=16)
        dd, di = idx.query(base[:20] + 1e-3, k=3)
        hd, hi = knn_host_kdtree(base[:20] + 1e-3, idx.tree, 3)
        np.testing.assert_allclose(dd, hd, rtol=1e-5, atol=1e-6)

    def test_stats_populated(self):
        pts, q = _data(4000, 128, 6, seed=5)
        idx = BufferKDTree(pts, height=4, n_chunks=2, tile_q=32)
        idx.query(q, k=4)
        st = idx.stats
        assert st.iterations > 0 and st.chunk_rounds >= st.iterations
        assert st.units_scanned > 0
        # tree pruning: far fewer points scanned than brute force
        assert st.points_scanned < 0.7 * 128 * 4000


class TestRecompileFree:
    def test_no_new_round_compiles_across_flushes(self):
        """Varying flush sizes / work-unit counts / query values must reuse
        the one compiled round (the W dimension is a while-loop bound, not a
        shape)."""
        pts, q = _data(4096, 256, 6, seed=1)
        idx = BufferKDTree(pts, height=4, n_chunks=2, tile_q=32)
        idx.query(q, k=5)                       # warm: compiles the round
        before = chunk_round_cache_size()
        rng = np.random.default_rng(9)
        for s in range(3):                      # same shapes, new content
            idx.query(rng.normal(size=(256, 6)).astype(np.float32), k=5)
        assert chunk_round_cache_size() == before

    def test_host_engine_plan_shapes_bounded(self):
        """The legacy path pads plans onto the fixed ladder: distinct padded
        shapes seen across ALL flushes stay tiny (no per-W recompiles)."""
        pts, q = _data(4096, 256, 6, seed=2)
        idx = BufferKDTree(pts, height=4, n_chunks=2, tile_q=32,
                           engine="host", buffer_size=64)
        idx.query(q, k=5)
        assert 1 <= idx.stats.plan_shapes <= 3


class TestUniformStore:
    def test_uniform_padding_shapes(self):
        slabs = np.arange(8 * 4 * 2, dtype=np.float32).reshape(8, 4, 2)
        store = ChunkedLeafStore(slabs, n_chunks=3, uniform=True)
        assert store.chunk_leaves == 3
        shapes = set()
        for cid, buf, lo in store.stream([0, 1, 2]):
            shapes.add(tuple(buf.shape))
            c_lo, c_hi = store.chunk_leaf_range(cid)
            # real rows match the original slabs
            np.testing.assert_allclose(
                np.asarray(buf)[: c_hi - c_lo], slabs[c_lo:c_hi]
            )
        assert shapes == {(3, 4, 2)}

    def test_uniform_chunk_of_leaf_covers_real_leaves(self):
        slabs = np.zeros((10, 2, 2), np.float32)
        store = ChunkedLeafStore(slabs, n_chunks=4, uniform=True)
        ids = store.chunk_of_leaf(np.arange(10))
        assert ids.min() >= 0 and ids.max() < 4
        for j in range(4):
            lo, hi = store.chunk_leaf_range(j)
            assert (ids[lo:hi] == j).all()

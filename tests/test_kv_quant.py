"""int8 KV-cache quantization: decode logits close to the bf16-cache path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.model import LanguageModel


@pytest.mark.parametrize("arch", ["qwen2_7b", "gemma2_27b"])
def test_int8_kv_decode_close(arch):
    cfg = get_config(arch, smoke=True)
    lm = LanguageModel(cfg)
    params, _ = lm.init(jax.random.key(0))
    B, steps = 2, 6
    toks = jax.random.randint(jax.random.key(1), (B, steps), 0, cfg.vocab_size)

    def run(kv_dtype):
        c = cfg.replace(kv_cache_dtype=kv_dtype)
        l2 = LanguageModel(c)
        caches, _ = l2.init_cache(B, 32)
        outs = []
        dec = jax.jit(lambda p, b, cc: l2.decode_step(p, b, cc))
        for t in range(steps):
            lg, caches = dec(params, {"tokens": toks[:, t:t+1],
                                      "pos": jnp.int32(t)}, caches)
            outs.append(np.asarray(lg[:, 0, : cfg.vocab_size], np.float32))
        return np.stack(outs)

    ref = run("bfloat16")
    q8 = run("int8")
    # int8 cache: logits within a few percent; argmax agreement high
    rel = np.abs(ref - q8).max() / (np.abs(ref).max() + 1e-9)
    assert rel < 0.08, rel
    agree = (ref.argmax(-1) == q8.argmax(-1)).mean()
    assert agree >= 0.8, agree


def test_int8_cache_is_smaller():
    cfg = get_config("qwen2_7b", smoke=True)
    lm_b = LanguageModel(cfg)
    lm_q = LanguageModel(cfg.replace(kv_cache_dtype="int8"))
    cb, _ = lm_b.abstract_cache(4, 128)
    cq, _ = lm_q.abstract_cache(4, 128)

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    assert nbytes(cq) < 0.6 * nbytes(cb)

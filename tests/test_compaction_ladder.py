"""Compaction-ladder behavior of the chunk-resident engine: rung shapes,
exact parity vs ``knn_brute`` across searches engineered to cross both
rungs mid-flight (and with m already below the smallest rung), the
compile-once-per-rung guarantee, and the measured-cost scheduler knobs."""

import numpy as np
import pytest

from repro.core import BufferKDTree
from repro.core.brute import knn_brute
from repro.core.chunked_jit import (
    COMPACTION_MIN,
    chunk_round_cache_size,
    compaction_cache_size,
    compaction_ladder,
)


def _data(n, m, d, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=(m, d)).astype(np.float32))


class TestLadderShapes:
    def test_smoke_shape_rungs(self):
        assert compaction_ladder(2000) == (512, 128)

    def test_descending_and_below_m(self):
        for m in (100, 500, 777, 2000, 10_000):
            ladder = compaction_ladder(m)
            assert all(r < m for r in ladder)
            assert list(ladder) == sorted(ladder, reverse=True)
            assert all(r >= COMPACTION_MIN for r in ladder)

    def test_tiny_m_has_no_rungs(self):
        assert compaction_ladder(COMPACTION_MIN) == ()
        assert compaction_ladder(8) == ()

    def test_pure_function_of_m(self):
        assert compaction_ladder(600) == compaction_ladder(600)


class TestLadderParity:
    """Shapes engineered so the live count crosses BOTH rungs mid-search."""

    def _oracle(self, pts, q, k):
        return knn_brute(q, pts, k)

    @pytest.mark.parametrize("n_chunks", [1, 3])
    def test_crosses_both_rungs_exact_vs_brute(self, n_chunks):
        # m=600 -> rungs (160, 48); deep-ish tree => slow retirement tail
        pts, q = _data(8000, 600, 6, seed=23)
        idx = BufferKDTree(pts, height=6, n_chunks=n_chunks, tile_q=32)
        dd, di = idx.query(q, k=7)
        assert idx.stats.compactions == 2, (
            "shape must cross both rungs to exercise the ladder "
            f"(got {idx.stats.compactions} compactions)"
        )
        assert idx.stats.tail_rounds > 0
        bd, bi = self._oracle(pts, q, 7)
        np.testing.assert_allclose(dd, bd, rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(di, bi)

    def test_m_below_smallest_rung_exact_vs_brute(self):
        pts, q = _data(3000, 24, 5, seed=3)   # m=24 < COMPACTION_MIN
        idx = BufferKDTree(pts, height=4, n_chunks=2, tile_q=16)
        dd, di = idx.query(q, k=5)
        assert idx.stats.compactions == 0
        assert idx.stats.tail_rounds == 0
        bd, bi = self._oracle(pts, q, 5)
        np.testing.assert_allclose(dd, bd, rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(di, bi)

    def test_ladder_rungs_compile_at_most_once(self):
        """Entered rungs add one fused-round compile each on the FIRST
        query; repeat queries (same batch shape, different content and
        different live-count trajectories) must add none."""
        pts, q = _data(8000, 608, 6, seed=29)   # distinct m: fresh shapes
        idx = BufferKDTree(pts, height=6, n_chunks=2, tile_q=32)
        round_before = chunk_round_cache_size()
        compact_before = compaction_cache_size()
        dd, di = idx.query(q, k=7)
        crossed = idx.stats.compactions
        assert crossed == 2
        round_after_warm = chunk_round_cache_size()
        # one compile for the full shape + one per rung entered, no more
        assert round_after_warm - round_before <= 1 + crossed
        assert compaction_cache_size() - compact_before <= crossed
        rng = np.random.default_rng(31)
        for seed in range(3):
            q2 = rng.normal(size=(608, 6)).astype(np.float32)
            idx.query(q2, k=7)
        assert chunk_round_cache_size() == round_after_warm
        assert compaction_cache_size() - compact_before <= crossed

    def test_warm_makes_compiled_set_trajectory_independent(self):
        """After an explicit warm, NO query (whatever rungs its live-count
        trajectory enters) may add a fused-round or gather compile."""
        pts, q = _data(8000, 616, 6, seed=41)   # distinct m: fresh shapes
        idx = BufferKDTree(pts, height=6, n_chunks=2, tile_q=32)
        before = chunk_round_cache_size()
        idx.warm(616, k=7)
        warmed_round = chunk_round_cache_size()
        warmed_compact = compaction_cache_size()
        # full shape + both rungs, in one deterministic step (<= because
        # rung shapes may already be shared with another tree's ladder)
        from repro.core.chunked_jit import compaction_ladder

        assert 1 <= warmed_round - before <= 1 + len(compaction_ladder(616))
        rng = np.random.default_rng(43)
        for _ in range(3):
            q2 = rng.normal(size=(616, 6)).astype(np.float32)
            idx.query(q2, k=7)
        assert chunk_round_cache_size() == warmed_round
        assert compaction_cache_size() == warmed_compact

    def test_compacted_stats_phases(self):
        pts, q = _data(8000, 600, 6, seed=23)
        idx = BufferKDTree(pts, height=6, n_chunks=2, tile_q=32)
        idx.query(q, k=7)
        st = idx.stats
        assert st.steady_rounds + st.tail_rounds == st.iterations
        assert st.steady_s > 0 and st.tail_s > 0
        # queries_advanced sums the CURRENT shape per round, so it must be
        # strictly below the no-ladder cost rounds * m
        assert st.queries_advanced < st.iterations * 600


class TestMeasuredCostScheduler:
    def test_pending_desc_order_and_starvation(self):
        from repro.core.chunked_jit import ChunkResidentEngine

        eng = ChunkResidentEngine.__new__(ChunkResidentEngine)
        eng.starvation_deadline = 2
        starve = np.zeros(4, np.int32)
        counts = np.array([5, 80, 0, 40])
        # threshold admits chunks 1 and 3; order is pending-desc
        visit = eng._visit_order(counts, threshold=20, starve=starve)
        assert visit.tolist() == [1, 3]
        assert starve.tolist() == [1, 0, 0, 0]
        # chunk 0 pends below threshold; after `deadline` skipped rounds it
        # must be force-visited
        visit = eng._visit_order(counts, threshold=20, starve=starve)
        assert visit.tolist() == [1, 3]
        visit = eng._visit_order(counts, threshold=20, starve=starve)
        assert 0 in visit.tolist()

    def test_forced_flush_when_nothing_meets_threshold(self):
        from repro.core.chunked_jit import ChunkResidentEngine

        eng = ChunkResidentEngine.__new__(ChunkResidentEngine)
        eng.starvation_deadline = 4
        starve = np.zeros(3, np.int32)
        visit = eng._visit_order(
            np.array([3, 7, 0]), threshold=100, starve=starve
        )
        assert visit.tolist() == [1, 0]   # all pending, densest first

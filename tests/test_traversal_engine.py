"""Traversal state machine + LazySearch engine correctness vs brute force."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import BufferKDTree, build_top_tree, knn_brute, knn_host_kdtree
from repro.core.traversal import reference_knn_via_traversal


def _data(n, m, d, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=(m, d)).astype(np.float32))


class TestReferenceTraversal:
    def test_exact_vs_brute(self):
        pts, q = _data(2000, 64, 6, seed=1)
        t = build_top_tree(pts, 4)
        dref, _ = reference_knn_via_traversal(q, t, 5)
        db, _ = knn_brute(q, pts, 5)
        np.testing.assert_allclose(dref, db, rtol=1e-4, atol=1e-5)


class TestLazySearchEngine:
    @pytest.mark.parametrize("n_chunks", [1, 2, 5])
    def test_exact_vs_brute(self, n_chunks):
        pts, q = _data(6000, 500, 8, seed=2)
        db, bi = knn_brute(q, pts, 10)
        idx = BufferKDTree(pts, height=5, n_chunks=n_chunks,
                           buffer_size=128, tile_q=64)
        dd, di = idx.query(q, k=10)
        np.testing.assert_allclose(dd, db, rtol=1e-4, atol=1e-4)
        assert (di == bi).mean() > 0.999  # ties may permute

    def test_k_edge_cases(self):
        pts, q = _data(300, 40, 4, seed=3)
        idx = BufferKDTree(pts, height=2, tile_q=32)
        for k in (1, 7):
            dd, di = idx.query(q, k=k)
            db, _ = knn_brute(q, pts, k)
            np.testing.assert_allclose(dd, db, rtol=1e-4, atol=1e-4)

    def test_duplicate_points(self):
        rng = np.random.default_rng(4)
        base = rng.normal(size=(50, 3)).astype(np.float32)
        pts = np.concatenate([base] * 4)  # every point 4x
        q = base[:20] + 1e-3
        idx = BufferKDTree(pts, height=3, tile_q=32)
        dd, di = idx.query(q, k=4)
        db, _ = knn_brute(q, pts, 4)
        np.testing.assert_allclose(dd, db, rtol=1e-4, atol=1e-4)

    def test_query_points_in_reference_set(self):
        pts, _ = _data(1000, 1, 5, seed=5)
        idx = BufferKDTree(pts, height=3, tile_q=32)
        dd, di = idx.query(pts[:64], k=1)
        assert np.allclose(dd[:, 0], 0.0, atol=1e-5)
        assert (di[:, 0] == np.arange(64)).all()

    def test_stats_show_pruning(self):
        pts, q = _data(20000, 256, 8, seed=6)
        idx = BufferKDTree(pts, height=6, tile_q=64)
        idx.query(q, k=5)
        # brute would be m*n; the tree should scan far less
        assert idx.stats.points_scanned < 0.6 * 256 * 20000

    def test_hostkdtree_baseline(self):
        pts, q = _data(3000, 128, 6, seed=7)
        t = build_top_tree(pts, 4)
        dd, di = knn_host_kdtree(q, t, 5)
        db, bi = knn_brute(q, pts, 5)
        np.testing.assert_allclose(dd, db, rtol=1e-4, atol=1e-4)


@given(
    n=st.integers(64, 600),
    m=st.integers(1, 60),
    d=st.integers(2, 7),
    k=st.integers(1, 8),
    h=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
@settings(max_examples=12)
def test_lazysearch_matches_brute_fuzz(n, m, d, k, h, seed):
    if (1 << h) > n or k > n:
        return
    pts, q = _data(n, m, d, seed)
    idx = BufferKDTree(pts, height=h, tile_q=32, buffer_size=64)
    dd, _ = idx.query(q, k=k)
    db, _ = knn_brute(q, pts, k)
    np.testing.assert_allclose(dd, db, rtol=1e-3, atol=1e-4)

"""Multi-device kNN paths (ring / forest / paper-style query chunking).

Each test spawns a subprocess with ``--xla_force_host_platform_device_count``
so the main pytest process keeps the real (1-CPU) device view.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str, devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import numpy as np
        import jax, jax.numpy as jnp
        jax.config.update("jax_cpu_enable_async_dispatch", False)  # see conftest
        from repro.compat import make_mesh, shard_map
        from repro.core import knn_brute
        rng = np.random.default_rng(0)
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=1800,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    return out.stdout


def test_ring_knn_exact():
    out = _run("""
        from repro.distributed.ring_knn import ring_knn_brute
        n, d, m, k = 8192, 8, 512, 10
        pts = rng.normal(size=(n, d)).astype(np.float32)
        q = rng.normal(size=(m, d)).astype(np.float32)
        mesh = make_mesh((8,), ("model",))
        d2, gi = ring_knn_brute(jnp.asarray(q), jnp.asarray(pts), k=k,
                                mesh=mesh, axis="model")
        bd, bi = knn_brute(q, pts, k)
        dd = np.sqrt(np.maximum(np.asarray(d2), 0))
        assert np.allclose(dd, bd, rtol=1e-4, atol=1e-4)
        assert (np.asarray(gi) == bi).mean() > 0.999
        print("RING_OK")
    """)
    assert "RING_OK" in out


def test_ring_knn_tiled_inner_loop():
    out = _run("""
        from repro.distributed import ring_knn
        n, d, m, k = 4096, 6, 256, 5
        pts = rng.normal(size=(n, d)).astype(np.float32)
        q = rng.normal(size=(m, d)).astype(np.float32)
        mesh = make_mesh((4,), ("model",))
        # force the tiled path: tile smaller than the local shard (1024)
        orig = ring_knn.REF_TILE
        ring_knn.REF_TILE = 256
        try:
            d2, gi = ring_knn.ring_knn_brute(jnp.asarray(q), jnp.asarray(pts),
                                             k=k, mesh=mesh, axis="model")
        finally:
            ring_knn.REF_TILE = orig
        bd, bi = knn_brute(q, pts, k)
        assert np.allclose(np.sqrt(np.maximum(np.asarray(d2), 0)), bd,
                           rtol=1e-4, atol=1e-4)
        print("TILED_OK")
    """, devices=4)
    assert "TILED_OK" in out


def test_forest_knn_exact():
    out = _run("""
        from repro.distributed.forest import build_forest, forest_knn, stack_forest
        n, d, m, k = 16384, 10, 512, 10
        pts = rng.normal(size=(n, d)).astype(np.float32)
        q = rng.normal(size=(m, d)).astype(np.float32)
        mesh = make_mesh((8,), ("model",))
        trees, offsets = build_forest(pts, 8, height=5)
        stk = stack_forest(trees)
        d_pad = trees[0].slabs.shape[-1]
        qpad = np.zeros((m, d_pad), np.float32); qpad[:, :d] = q
        fd, fi = forest_knn(jnp.asarray(qpad), stk, jnp.asarray(offsets),
                            k=k, tq=64, first_leaf_heap=1 << 5,
                            mesh=mesh, axis="model")
        bd, bi = knn_brute(q, pts, k)
        assert np.allclose(np.sqrt(np.maximum(np.asarray(fd), 0)), bd,
                           rtol=1e-4, atol=1e-4)
        assert (np.asarray(fi) == bi).mean() > 0.999
        print("FOREST_OK")
    """)
    assert "FOREST_OK" in out


def test_paper_multi_device_query_chunking():
    """Paper §3.2: queries split into big chunks, one engine per device."""
    out = _run("""
        from repro.distributed.sharded import multi_device_query
        n, d, m, k = 6000, 8, 600, 10
        pts = rng.normal(size=(n, d)).astype(np.float32)
        q = rng.normal(size=(m, d)).astype(np.float32)
        dd, di = multi_device_query(pts, q, k, devices=jax.devices()[:4],
                                    height=4, tile_q=64)
        bd, bi = knn_brute(q, pts, k)
        assert np.allclose(dd, bd, rtol=1e-4, atol=1e-4)
        assert (di == bi).mean() > 0.999
        print("MULTIDEV_OK")
    """, devices=4)
    assert "MULTIDEV_OK" in out


def test_ef_int8_gradient_compression():
    out = _run("""
        from repro.training.compression import ef_int8_allreduce, init_error_state
        mesh = make_mesh((4,), ("dp",))
        from jax.sharding import PartitionSpec as P

        def body(g, e):
            m, e2 = ef_int8_allreduce({"w": g}, {"w": e}, "dp")
            return m["w"], e2["w"]

        fn = jax.jit(shard_map(body, mesh=mesh,
                               in_specs=(P("dp"), P("dp")),
                               out_specs=(P(), P("dp"))))
        g = rng.normal(size=(4, 1000)).astype(np.float32)
        e = np.zeros((4, 1000), np.float32)
        exact = g.mean(axis=0)
        # single step: quantized mean close to exact (the per-shard block
        # keeps a leading dim of 1 -> index [0])
        m, e2 = fn(jnp.asarray(g), jnp.asarray(e))
        m = np.asarray(m).reshape(-1)
        err1 = np.abs(m - exact).max() / (np.abs(exact).max() + 1e-9)
        assert err1 < 0.05, err1
        # error feedback: accumulated mean over repeated steps converges
        acc_q = np.zeros(1000); acc_x = np.zeros(1000)
        ej = jnp.asarray(e)
        for _ in range(20):
            mj, ej = fn(jnp.asarray(g), ej)
            acc_q += np.asarray(mj).reshape(-1); acc_x += exact
        rel = np.abs(acc_q - acc_x).max() / (np.abs(acc_x).max() + 1e-9)
        assert rel < 0.01, rel
        print("EF_OK")
    """, devices=4)
    assert "EF_OK" in out

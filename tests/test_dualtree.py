"""core/dualtree: node-pair frontier ops vs brute-force oracles.

Parity fixtures use integer-lattice points so every squared pair distance
is an exact fp32 integer, and radii / histogram edges whose squares are
NON-integers — no distance can straddle a boundary between the kernels'
fp32 arithmetic and the oracles' float64, making radius and pair_count
bit-exact comparisons rather than tolerance games.  KDE is checked
against its declared contract: ``|approx - exact| <= rtol*exact + atol``
(plus fp32 kernel rounding slack).
"""

import numpy as np
import pytest

from repro.core.chunked import ChunkedLeafStore
from repro.core.dualtree import (
    PAIR_RUNGS,
    DualTree,
    dualtree_cache_size,
    kde_brute,
    node_bounds,
    pair_count_brute,
    radius_brute,
)
from repro.core.lazysearch import SearchStats
from repro.core.toptree import build_top_tree

# non-integer-squared boundaries (see module doc)
EDGES = np.array([0.5, 3.5, 7.5, 11.5, 16.5, 25.5])
RADIUS = float(np.sqrt(7.5))


def lattice(n, d, seed=0, span=12):
    rng = np.random.default_rng(seed)
    return rng.integers(0, span, size=(n, d)).astype(np.float32)


def clustered(n, d, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32)
    pts = centers[rng.integers(0, 8, n)] + 0.05 * rng.normal(
        size=(n, d)
    ).astype(np.float32)
    return pts.astype(np.float32)


def csr_rows_equal(ip_a, ix_a, ip_b, ix_b):
    """Same neighbor SETS per row (tie order among equal distances is
    stream-dependent); indptr must match exactly."""
    assert np.array_equal(ip_a, ip_b)
    for i in range(len(ip_a) - 1):
        assert set(ix_a[ip_a[i]:ip_a[i + 1]].tolist()) == set(
            ix_b[ip_b[i]:ip_b[i + 1]].tolist()
        ), f"row {i}"


class TestNodeBounds:
    def test_boxes_match_brute_leaf_partition(self):
        pts = lattice(500, 3, seed=1)
        tree = build_top_tree(pts, 4)
        b = node_bounds(tree)
        nl = tree.n_leaves
        sizes = tree.leaf_sizes()
        # leaves: box over each leaf's real rows
        for j in range(nl):
            rows = tree.points_padded[j, : sizes[j], : tree.d]
            np.testing.assert_array_equal(b.lo[nl + j], rows.min(0))
            np.testing.assert_array_equal(b.hi[nl + j], rows.max(0))
            assert b.count[nl + j] == sizes[j]
        # internal nodes: union of children, counts add
        for v in range(nl - 1, 0, -1):
            np.testing.assert_array_equal(
                b.lo[v], np.minimum(b.lo[2 * v], b.lo[2 * v + 1])
            )
            np.testing.assert_array_equal(
                b.hi[v], np.maximum(b.hi[2 * v], b.hi[2 * v + 1])
            )
            assert b.count[v] == b.count[2 * v] + b.count[2 * v + 1]
        assert b.count[1] == 500


def stores(pts, height):
    """The store variants every op must agree across: resident, chunked,
    and quantized (which forces DualTree's private fp32 rebuild)."""
    tree = build_top_tree(pts, height)
    yield "resident", DualTree(tree)
    slabs = tree.points_padded
    dp = max(8, -(-tree.d // 8) * 8)
    if dp != tree.d:
        pad = np.zeros((slabs.shape[0], slabs.shape[1], dp - tree.d), np.float32)
        slabs = np.concatenate([slabs, pad], axis=-1)
    yield "chunked3", DualTree(
        tree,
        ChunkedLeafStore(
            slabs, n_chunks=3, uniform=True, leaf_sizes=tree.leaf_sizes()
        ),
    )
    yield "quantized", DualTree(
        tree,
        ChunkedLeafStore(
            slabs, n_chunks=2, uniform=True, leaf_sizes=tree.leaf_sizes(),
            precision="int8",
        ),
    )


class TestRadius:
    @pytest.mark.parametrize("n,m,d,height", [(2000, 150, 3, 4), (700, 64, 5, 5)])
    def test_parity_all_store_variants(self, n, m, d, height):
        pts = lattice(n, d, seed=n)
        q = lattice(m, d, seed=n + 1)
        bi, bj, bd = radius_brute(q, pts, RADIUS)
        for name, dual in stores(pts, height):
            ip, ix, dd, stats = dual.radius(q, RADIUS)
            csr_rows_equal(ip, ix, bi, bj)
            # distances ascending within each row, all <= r
            for i in range(m):
                row = dd[ip[i]:ip[i + 1]]
                assert np.all(np.diff(row) >= 0), (name, i)
            assert np.all(dd <= np.float32(RADIUS))
            assert isinstance(stats, SearchStats)
            assert stats.units_scanned > 0

    def test_prunes_vs_all_pairs(self):
        # two well-separated lattice blocks: cross pairs must prune
        pts = np.concatenate([lattice(600, 3, seed=2),
                              lattice(600, 3, seed=3) + 1000.0])
        q = pts[::10] + 0.25
        dual = DualTree(build_top_tree(pts, 5))
        ip, ix, dd, stats = dual.radius(q, RADIUS)
        total = dual.tree.n_leaves * -(-len(q) // 64)
        assert stats.units_scanned < total  # leaf pairs visited < full grid
        bi, bj, _ = radius_brute(q, pts, RADIUS)
        csr_rows_equal(ip, ix, bi, bj)

    def test_single_query_fallback(self):
        pts = lattice(300, 4, seed=4)
        dual = DualTree(build_top_tree(pts, 3))
        for q in (pts[:1] + 0.25, np.zeros((0, 4), np.float32)):
            ip, ix, dd, stats = dual.radius(q, RADIUS)
            bi, bj, _ = radius_brute(q, pts, RADIUS)
            csr_rows_equal(ip, ix, bi, bj)

    def test_negative_radius_rejected(self):
        dual = DualTree(build_top_tree(lattice(64, 2), 2))
        with pytest.raises(ValueError):
            dual.radius(np.zeros((3, 2), np.float32), -1.0)


class TestKDE:
    @pytest.mark.parametrize("kernel", ["gaussian", "tophat"])
    def test_within_declared_tolerance(self, kernel):
        pts = clustered(3000, 3, seed=5)
        q = clustered(200, 3, seed=6)
        h, rtol, atol = 0.3, 1e-2, 1e-9
        exact = kde_brute(q, pts, h, kernel=kernel).astype(np.float64)
        for name, dual in stores(pts, 4):
            dens, err, stats = dual.kde(
                q, h, rtol=rtol, atol=atol, kernel=kernel
            )
            # declared contract + fp32 kernel rounding slack
            bound = rtol * exact + atol + 1e-5 * np.maximum(exact, 1.0)
            assert np.all(np.abs(dens.astype(np.float64) - exact) <= bound), name
            assert err >= 0.0

    def test_tophat_exact_and_consistent_with_radius(self):
        pts = lattice(1500, 3, seed=7)
        q = lattice(100, 3, seed=8)
        dual = DualTree(build_top_tree(pts, 4))
        dens, err, _ = dual.kde(q, RADIUS, kernel="tophat")
        assert err == 0.0  # tophat prune is exact
        ip, _, _, _ = dual.radius(q, RADIUS)
        counts = np.diff(ip)
        np.testing.assert_allclose(
            dens, counts.astype(np.float32) / len(pts), rtol=1e-6
        )

    def test_approximation_actually_prunes(self):
        # clustered data with a loose tolerance must midpoint-approximate
        # some far-field pairs (fewer leaf pairs than the exact run)
        pts = clustered(4000, 3, seed=9)
        q = clustered(256, 3, seed=10)
        dual = DualTree(build_top_tree(pts, 5))
        _, err_loose, s_loose = dual.kde(q, 0.1, rtol=0.3, atol=1e-6)
        _, _, s_tight = dual.kde(q, 0.1, rtol=1e-12, atol=0.0)
        assert s_loose.units_scanned < s_tight.units_scanned
        assert err_loose > 0.0

    def test_bad_kernel_rejected(self):
        dual = DualTree(build_top_tree(lattice(64, 2), 2))
        with pytest.raises(ValueError):
            dual.kde(np.zeros((3, 2), np.float32), 1.0, kernel="sinc")


class TestPairCount:
    @pytest.mark.parametrize("n,d,height", [(1500, 3, 4), (900, 5, 5)])
    def test_parity_all_store_variants(self, n, d, height):
        pts = lattice(n, d, seed=n)
        ref = pair_count_brute(pts, EDGES)
        np_ref, _ = np.histogram(np.float32(0), bins=EDGES)  # shape check only
        assert ref.shape == np_ref.shape
        for name, dual in stores(pts, height):
            hist, stats = dual.pair_count(EDGES)
            assert np.array_equal(hist, ref), name
            assert stats.units_scanned >= 0

    def test_matches_numpy_histogram_oracle(self):
        pts = lattice(800, 3, seed=11)
        diff = pts[:, None, :].astype(np.float64) - pts[None, :, :]
        dist = np.sqrt((diff * diff).sum(-1))
        mask = ~np.eye(len(pts), dtype=bool)
        ref, _ = np.histogram(dist[mask], bins=EDGES)
        hist, _ = DualTree(build_top_tree(pts, 4)).pair_count(EDGES)
        assert np.array_equal(hist, ref.astype(np.int64))

    def test_zero_leading_edge_excludes_self_pairs(self):
        pts = lattice(500, 3, seed=12)
        edges = np.array([0.0, 3.5, 7.5, 16.5])
        diff = pts[:, None, :].astype(np.float64) - pts[None, :, :]
        dist = np.sqrt((diff * diff).sum(-1))
        mask = ~np.eye(len(pts), dtype=bool)
        ref, _ = np.histogram(dist[mask], bins=edges)
        hist, _ = DualTree(build_top_tree(pts, 4)).pair_count(edges)
        assert np.array_equal(hist, ref.astype(np.int64))

    def test_total_count_conserved(self):
        pts = lattice(600, 4, seed=13, span=6)
        span_max = 4 * 6 * 6 * 4  # > any possible squared distance
        edges = np.array([0.0, 1.5, float(np.sqrt(span_max))])
        hist, _ = DualTree(build_top_tree(pts, 4)).pair_count(edges)
        n = len(pts)
        assert hist.sum() == n * (n - 1)  # every ordered non-self pair

    def test_bad_edges_rejected(self):
        dual = DualTree(build_top_tree(lattice(64, 2), 2))
        for bad in ([1.0], [2.0, 1.0], [-1.0, 2.0]):
            with pytest.raises(ValueError):
                dual.pair_count(np.asarray(bad, np.float64))


class TestRecompileDiscipline:
    def test_warm_then_new_operands_no_compiles(self):
        pts = lattice(2500, 3, seed=14)
        q = lattice(300, 3, seed=15)
        dual = DualTree(build_top_tree(pts, 4))
        dual.warm(("radius", "kde", "pair_count"), m=len(q), n_edges=len(EDGES))
        before = dualtree_cache_size()
        # new radii / bandwidths / edge VALUES are operands, not shapes
        for r in (0.5, RADIUS, 9.0):
            dual.radius(q, r)
        for h in (0.4, 2.0):
            dual.kde(q, h)
            dual.kde(q, h, kernel="tophat")
        dual.pair_count(EDGES)
        dual.pair_count(EDGES * 2.0)
        assert dualtree_cache_size() == before
        # a different edge COUNT is a new kernel shape: compiles once more
        dual.pair_count(np.array([0.5, 1.5, 2.5]))
        assert dualtree_cache_size() == before + 1

    def test_rungs_cover_pair_batches(self):
        assert tuple(sorted(PAIR_RUNGS)) == PAIR_RUNGS
        assert PAIR_RUNGS[0] >= 1

"""Serving engine (continuous batching) + kNN-LM integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.model import LanguageModel
from repro.models.transformer import grow_cache
from repro.serving.engine import Request, ServeEngine
from repro.serving.knnlm import KNNLM


@pytest.fixture(scope="module")
def lm_and_params():
    cfg = get_config("qwen15_0_5b", smoke=True)
    lm = LanguageModel(cfg)
    params, _ = lm.init(jax.random.key(0))
    return lm, params


class TestServeEngine:
    def test_greedy_matches_reference_decode(self, lm_and_params):
        """Engine output must equal a hand-rolled prefill+greedy loop."""
        lm, params = lm_and_params
        cfg = lm.cfg
        prompt = np.array([3, 14, 15, 9], np.int32)
        new = 6

        # reference: replay prompt through decode path, then greedy
        caches, _ = lm.init_cache(1, 64)
        dec = jax.jit(lambda p, b, c: lm.decode_step(p, b, c))
        for t, tok in enumerate(prompt[:-1]):
            _, caches = dec(params, {"tokens": jnp.full((1, 1), tok, jnp.int32),
                                     "pos": jnp.int32(t)}, caches)
        ref = []
        last = int(prompt[-1])
        for i in range(new):
            lg, caches = dec(params,
                             {"tokens": jnp.full((1, 1), last, jnp.int32),
                              "pos": jnp.int32(len(prompt) - 1 + i)}, caches)
            last = int(jnp.argmax(lg[0, 0, : cfg.vocab_size]))
            ref.append(last)

        eng = ServeEngine(lm, params, slots=2, max_len=64)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=new))
        done = eng.run()
        assert done[0].out_tokens == ref

    def test_multiple_requests_slot_reuse(self, lm_and_params):
        lm, params = lm_and_params
        eng = ServeEngine(lm, params, slots=2, max_len=64)
        for rid in range(5):
            eng.submit(Request(rid=rid,
                               prompt=np.arange(2 + rid, dtype=np.int32) + 1,
                               max_new_tokens=3 + rid % 2))
        done = eng.run()
        assert sorted(done) == list(range(5))
        for rid, req in done.items():
            assert len(req.out_tokens) == 3 + rid % 2

    def test_isolation_between_slots(self, lm_and_params):
        """A request's output must not depend on its co-batched neighbors."""
        lm, params = lm_and_params
        prompt = np.array([7, 8, 9], np.int32)
        eng1 = ServeEngine(lm, params, slots=2, max_len=64)
        eng1.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        solo = eng1.run()[0].out_tokens

        eng2 = ServeEngine(lm, params, slots=2, max_len=64)
        eng2.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        eng2.submit(Request(rid=1, prompt=np.array([100, 200], np.int32),
                            max_new_tokens=4))
        both = eng2.run()[0].out_tokens
        assert solo == both


class TestKNNLM:
    def test_interpolated_distribution(self, lm_and_params):
        lm, params = lm_and_params
        cfg = lm.cfg
        knn = KNNLM(lm, params, proj_dim=8, k=5, lam=0.3, tree_height=3)
        rng = np.random.default_rng(0)
        corpus = rng.integers(0, cfg.vocab_size, size=(8, 33)).astype(np.int32)
        knn.build_datastore(corpus)
        q = corpus[:4, :16]
        p = knn.next_token_probs(q)
        assert p.shape == (4, cfg.vocab_size)
        np.testing.assert_allclose(p.sum(axis=1), 1.0, rtol=1e-3)
        assert (p >= 0).all()

    def test_retrieval_exactness(self, lm_and_params):
        """The buffer k-d tree must return the true NNs of projected keys."""
        from repro.core import knn_brute

        lm, params = lm_and_params
        cfg = lm.cfg
        knn = KNNLM(lm, params, proj_dim=8, k=5, tree_height=3)
        rng = np.random.default_rng(1)
        corpus = rng.integers(0, cfg.vocab_size, size=(8, 33)).astype(np.int32)
        knn.build_datastore(corpus)
        keys = knn.embed_contexts(corpus[:, :-1])
        dd, di = knn.index.query(keys[:16], k=5)
        bd, bi = knn_brute(keys[:16], keys, 5)
        np.testing.assert_allclose(dd, bd, rtol=1e-3, atol=1e-4)

    def test_lam_zero_equals_lm(self, lm_and_params):
        lm, params = lm_and_params
        cfg = lm.cfg
        knn = KNNLM(lm, params, proj_dim=8, k=3, lam=0.0, tree_height=3)
        rng = np.random.default_rng(2)
        corpus = rng.integers(0, cfg.vocab_size, size=(4, 17)).astype(np.int32)
        knn.build_datastore(corpus)
        q = corpus[:2, :8]
        p = knn.next_token_probs(q)
        logits, _ = jax.jit(lambda pp, b: lm.forward(pp, b))(
            params, {"tokens": jnp.asarray(q)})
        p_lm = np.asarray(jax.nn.softmax(logits[:, -1, : cfg.vocab_size], -1))
        np.testing.assert_allclose(p, p_lm, rtol=1e-4, atol=1e-5)

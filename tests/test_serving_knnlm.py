"""Serving engine (continuous batching) + kNN-LM integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.model import LanguageModel
from repro.models.transformer import grow_cache
from repro.serving.engine import Request, ServeEngine
from repro.serving.knnlm import KNNLM


@pytest.fixture(scope="module")
def lm_and_params():
    cfg = get_config("qwen15_0_5b", smoke=True)
    lm = LanguageModel(cfg)
    params, _ = lm.init(jax.random.key(0))
    return lm, params


class TestServeEngine:
    def test_greedy_matches_reference_decode(self, lm_and_params):
        """Engine control flow must reproduce a hand-rolled prefill+greedy
        loop.

        The reference replays the prompt plus the ENGINE's emitted tokens and
        checks each emitted token is (near-)argmax of the reference logits.
        Matching logits-with-tolerance rather than exact token sequences keeps
        the test meaningful: XLA CPU matmuls are not call-to-call bitwise
        stable (oneDNN primitive re-selection), and this random-init smoke
        model has tiny argmax margins, so exact greedy chains are chaotic.  A
        real control-flow bug (wrong pos, wrong slot, cache corruption) makes
        the reference logits disagree by far more than the tolerance.
        """
        lm, params = lm_and_params
        cfg = lm.cfg
        prompt = np.array([3, 14, 15, 9], np.int32)
        new = 6

        eng = ServeEngine(lm, params, slots=2, max_len=64)
        dec = eng._decode
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=new))
        out = eng.run()[0].out_tokens
        assert len(out) == new

        # reference: replay prompt + engine tokens through the decode path,
        # batched exactly like the engine (slot 1 inactive)
        caches, _ = lm.init_cache(2, 64)

        def step1(tok, pos, caches):
            batch = {
                "tokens": jnp.asarray(np.array([[tok], [0]], np.int32)),
                "pos": jnp.asarray(np.array([pos, 0], np.int32)),
                "active": jnp.asarray(np.array([True, False])),
            }
            return dec(params, batch, caches)

        for t, tok in enumerate(prompt[:-1]):
            _, caches = step1(int(tok), t, caches)
        stream = [int(prompt[-1])] + out[:-1]
        for i, tok in enumerate(stream):
            lg, caches = step1(tok, len(prompt) - 1 + i, caches)
            row = np.asarray(lg[0, 0, : cfg.vocab_size], np.float32)
            assert row[out[i]] >= row.max() - 1e-3, (
                f"step {i}: engine token {out[i]} not argmax of reference "
                f"logits (margin {row.max() - row[out[i]]})"
            )

    def test_multiple_requests_slot_reuse(self, lm_and_params):
        lm, params = lm_and_params
        eng = ServeEngine(lm, params, slots=2, max_len=64)
        for rid in range(5):
            eng.submit(Request(rid=rid,
                               prompt=np.arange(2 + rid, dtype=np.int32) + 1,
                               max_new_tokens=3 + rid % 2))
        done = eng.run()
        assert sorted(done) == list(range(5))
        for rid, req in done.items():
            assert len(req.out_tokens) == 3 + rid % 2

    def test_isolation_between_slots(self, lm_and_params):
        """A request's logits must not depend on its co-batched neighbors.

        Compares slot-0 logits (same token stream) with a lone vs an occupied
        slot 1, with a tolerance far above benign run-to-run float jitter but
        far below any real cross-slot leak (an unmasked cache write changes
        logits at O(1) magnitude).
        """
        lm, params = lm_and_params
        cfg = lm.cfg
        prompt = np.array([7, 8, 9], np.int32)

        eng1 = ServeEngine(lm, params, slots=2, max_len=64)
        eng1.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        eng1._admit()

        eng2 = ServeEngine(lm, params, slots=2, max_len=64)
        eng2.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        eng2.submit(Request(rid=1, prompt=np.array([100, 200], np.int32),
                            max_new_tokens=4))
        eng2._admit()

        # identical slot-0 stream through both engines; slot 1 decodes its
        # own tokens in eng2 and idles in eng1
        stream = [9, 42, 7, 300]
        t1 = 200
        for i, tok in enumerate(stream):
            lg1 = eng1._run_tokens(np.array([tok, 0], np.int32),
                                   np.array([2 + i, 0]),
                                   np.array([True, False]))
            lg2 = eng2._run_tokens(np.array([tok, t1], np.int32),
                                   np.array([2 + i, 1 + i]),
                                   np.array([True, True]))
            r1 = np.asarray(lg1[0, 0, : cfg.vocab_size], np.float32)
            r2 = np.asarray(lg2[0, 0, : cfg.vocab_size], np.float32)
            np.testing.assert_allclose(r1, r2, atol=1e-3, rtol=0)
            t1 = int(np.argmax(np.asarray(lg2[1, 0, : cfg.vocab_size])))


class TestKNNLM:
    def test_interpolated_distribution(self, lm_and_params):
        lm, params = lm_and_params
        cfg = lm.cfg
        knn = KNNLM(lm, params, proj_dim=8, k=5, lam=0.3, tree_height=3)
        rng = np.random.default_rng(0)
        corpus = rng.integers(0, cfg.vocab_size, size=(8, 33)).astype(np.int32)
        knn.build_datastore(corpus)
        q = corpus[:4, :16]
        p = knn.next_token_probs(q)
        assert p.shape == (4, cfg.vocab_size)
        np.testing.assert_allclose(p.sum(axis=1), 1.0, rtol=1e-3)
        assert (p >= 0).all()

    def test_retrieval_exactness(self, lm_and_params):
        """The buffer k-d tree must return the true NNs of projected keys."""
        from repro.core import knn_brute

        lm, params = lm_and_params
        cfg = lm.cfg
        knn = KNNLM(lm, params, proj_dim=8, k=5, tree_height=3)
        rng = np.random.default_rng(1)
        corpus = rng.integers(0, cfg.vocab_size, size=(8, 33)).astype(np.int32)
        knn.build_datastore(corpus)
        keys = knn.embed_contexts(corpus[:, :-1])
        dd, di = knn.index.query(keys[:16], k=5)
        bd, bi = knn_brute(keys[:16], keys, 5)
        np.testing.assert_allclose(dd, bd, rtol=1e-3, atol=1e-4)

    def test_mutable_datastore_extends_incrementally(self, lm_and_params):
        """Streaming kNN-LM: mutable=True plans the dynamic engine, and
        extend_datastore appends (key -> next-token) pairs with ids that
        keep indexing the value array — no rebuild, retrieval stays exact
        over the grown store."""
        from repro.api import MutabilityError
        from repro.core import knn_brute

        lm, params = lm_and_params
        cfg = lm.cfg
        knn = KNNLM(lm, params, proj_dim=8, k=5, mutable=True)
        rng = np.random.default_rng(3)
        corpus = rng.integers(0, cfg.vocab_size, size=(6, 25)).astype(np.int32)
        knn.build_datastore(corpus)
        assert knn.index.engine_name == "dynamic"
        n0 = knn.values.shape[0]

        extra = rng.integers(0, cfg.vocab_size, size=(4, 25)).astype(np.int32)
        ids = knn.extend_datastore(extra)
        assert ids.tolist() == list(range(n0, n0 + 4 * 24))
        assert knn.values.shape[0] == n0 + 4 * 24

        keys_all = np.concatenate([
            knn.embed_contexts(corpus[:, :-1]),
            knn.embed_contexts(extra[:, :-1]),
        ])
        dd, di = knn.index.query(keys_all[:16], k=5)
        bd, _ = knn_brute(keys_all[:16], keys_all, 5)
        np.testing.assert_allclose(dd, bd, rtol=1e-3, atol=1e-4)
        assert (di < knn.values.shape[0]).all()   # every id has a value

        p = knn.next_token_probs(extra[:2, :8])
        np.testing.assert_allclose(p.sum(axis=1), 1.0, rtol=1e-3)

        # an immutable store refuses to grow, loudly and typed
        knn2 = KNNLM(lm, params, proj_dim=8, k=3, tree_height=3)
        knn2.build_datastore(corpus)
        with pytest.raises(MutabilityError):
            knn2.extend_datastore(extra)

    def test_datastore_warm_restart_roundtrip(self, lm_and_params, tmp_path):
        """save_datastore / load_datastore: a restarted server answers
        identically WITHOUT re-embedding or re-indexing the corpus —
        including keys streamed in after the snapshot (WAL replay)."""
        from repro.api import IndexSpec

        lm, params = lm_and_params
        cfg = lm.cfg
        root = str(tmp_path / "store")
        knn = KNNLM(
            lm, params, proj_dim=8, k=5, mutable=True,
            index_spec=IndexSpec(persist_dir=root),
        )
        rng = np.random.default_rng(5)
        corpus = rng.integers(0, cfg.vocab_size, size=(6, 25)).astype(np.int32)
        knn.build_datastore(corpus)
        knn.save_datastore()
        extra = rng.integers(0, cfg.vocab_size, size=(3, 25)).astype(np.int32)
        knn.extend_datastore(extra)
        knn.save_datastore()   # values stay in lockstep with the WAL
        q = corpus[:3, :10]
        p0 = knn.next_token_probs(q)

        knn2 = KNNLM(lm, params, proj_dim=8, k=5, mutable=True, seed=0)
        knn2.load_datastore(root)
        np.testing.assert_array_equal(knn2.values, knn.values)
        assert knn2.index.n == knn.index.n
        p1 = knn2.next_token_probs(q)
        np.testing.assert_allclose(p0, p1, rtol=1e-4, atol=1e-5)

        # the restarted datastore keeps streaming
        more = rng.integers(0, cfg.vocab_size, size=(2, 25)).astype(np.int32)
        knn2.extend_datastore(more)
        assert knn2.index.n == knn2.values.shape[0]

    def test_stale_values_detected_on_load(self, lm_and_params, tmp_path):
        """Keys replayed from the WAL whose values were never saved must
        be refused, not served as silently-wrong tokens."""
        from repro.api import IndexSpec

        lm, params = lm_and_params
        cfg = lm.cfg
        root = str(tmp_path / "store")
        knn = KNNLM(
            lm, params, proj_dim=8, k=3, mutable=True,
            index_spec=IndexSpec(persist_dir=root),
        )
        rng = np.random.default_rng(6)
        corpus = rng.integers(0, cfg.vocab_size, size=(4, 17)).astype(np.int32)
        knn.build_datastore(corpus)
        knn.save_datastore()
        # extend WITHOUT saving: keys hit the WAL, values stay in memory
        knn.extend_datastore(
            rng.integers(0, cfg.vocab_size, size=(2, 17)).astype(np.int32)
        )
        knn.drain_index()
        knn2 = KNNLM(lm, params, proj_dim=8, k=3, mutable=True)
        with pytest.raises(RuntimeError, match="values predate"):
            knn2.load_datastore(root)

    def test_lam_zero_equals_lm(self, lm_and_params):
        lm, params = lm_and_params
        cfg = lm.cfg
        knn = KNNLM(lm, params, proj_dim=8, k=3, lam=0.0, tree_height=3)
        rng = np.random.default_rng(2)
        corpus = rng.integers(0, cfg.vocab_size, size=(4, 17)).astype(np.int32)
        knn.build_datastore(corpus)
        q = corpus[:2, :8]
        p = knn.next_token_probs(q)
        logits, _ = jax.jit(lambda pp, b: lm.forward(pp, b))(
            params, {"tokens": jnp.asarray(q)})
        p_lm = np.asarray(jax.nn.softmax(logits[:, -1, : cfg.vocab_size], -1))
        np.testing.assert_allclose(p, p_lm, rtol=1e-4, atol=1e-5)


class TestLockstepAdmission:
    def test_admission_replay_cost_is_max_not_sum(self, lm_and_params):
        """Admitting R requests together must replay their prompts in
        LOCKSTEP: max(prompt_len - 1) jitted dispatches, not the sum —
        the regression that made every admission round O(sum of prompts)."""
        lm, params = lm_and_params
        eng = ServeEngine(lm, params, slots=2, max_len=64)
        calls = []
        orig = eng._run_tokens
        eng._run_tokens = lambda *a: (calls.append(1), orig(*a))[1]
        eng.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32) + 1,
                           max_new_tokens=2))
        eng.submit(Request(rid=1, prompt=np.arange(3, dtype=np.int32) + 1,
                           max_new_tokens=2))
        eng._admit()
        assert len(calls) == 4, (
            f"expected max(4, 2) = 4 lockstep replay dispatches, got "
            f"{len(calls)} (sum would be 6)"
        )
        assert eng.slot_pos.tolist() == [4, 2]
        # and the requests still decode to completion afterwards
        eng._run_tokens = orig
        done = eng.run()
        assert sorted(done) == [0, 1]

    def test_lockstep_admission_matches_solo_admission(self, lm_and_params):
        """Logits for a request admitted WITH a neighbor must match the
        same request admitted alone (the active mask isolates the shorter
        prompt's slot after its replay finishes)."""
        lm, params = lm_and_params
        cfg = lm.cfg
        prompt = np.array([3, 14, 15, 9, 2], np.int32)

        def first_logits(with_neighbor):
            eng = ServeEngine(lm, params, slots=2, max_len=64)
            eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
            if with_neighbor:
                eng.submit(Request(rid=1, prompt=np.array([7, 8], np.int32),
                                   max_new_tokens=1))
            eng._admit()
            lg = eng._run_tokens(
                np.array([int(prompt[-1]), 0], np.int32),
                eng.slot_pos.astype(np.int64).copy(),
                np.array([True, False]),
            )
            return np.asarray(lg[0, 0, : cfg.vocab_size], np.float32)

        np.testing.assert_allclose(
            first_logits(False), first_logits(True), atol=1e-3, rtol=0
        )


class TestKNNLMServing:
    def test_next_token_probs_parity_through_server(self, lm_and_params):
        """serve() must not change what the model computes: the served
        path (per-row admission queue + rung micro-batches) returns the
        same interpolated distribution as direct batch retrieval."""
        from repro.api import IndexSpec

        lm, params = lm_and_params
        cfg = lm.cfg
        knn = KNNLM(lm, params, proj_dim=8, k=5, lam=0.3,
                    index_spec=IndexSpec(engine="streaming"))
        rng = np.random.default_rng(5)
        corpus = rng.integers(0, cfg.vocab_size, size=(8, 33)).astype(np.int32)
        knn.build_datastore(corpus)
        toks = rng.integers(0, cfg.vocab_size, size=(4, 12)).astype(np.int32)
        p_direct = knn.next_token_probs(toks)
        server = knn.serve(max_batch=16, default_deadline_ms=25.0)
        try:
            p_served = knn.next_token_probs(toks)
            assert server.stats()["completed"] == 4
        finally:
            knn.unserve()
        np.testing.assert_allclose(p_direct, p_served, rtol=1e-5, atol=1e-6)
        # after unserve() retrieval reverts to direct batch queries
        p_after = knn.next_token_probs(toks)
        np.testing.assert_allclose(p_direct, p_after, rtol=1e-5, atol=1e-6)

    def test_serve_requires_streaming_engine(self, lm_and_params):
        from repro.api import StreamingUnsupported

        lm, params = lm_and_params
        cfg = lm.cfg
        knn = KNNLM(lm, params, proj_dim=8, k=3, tree_height=3)
        with pytest.raises(RuntimeError, match="no datastore"):
            knn.serve()
        rng = np.random.default_rng(6)
        corpus = rng.integers(0, cfg.vocab_size, size=(4, 17)).astype(np.int32)
        knn.build_datastore(corpus)           # default plan: not streaming
        with pytest.raises(StreamingUnsupported):
            knn.serve()

"""Sequence-parallel residual stream: numerical equivalence (subprocess,
4 host devices) — the §Perf B1 optimization must not change the function."""

import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_seq_shard_equivalence():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        import jax, jax.numpy as jnp
        jax.config.update("jax_cpu_enable_async_dispatch", False)  # see conftest
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.configs.base import get_config
        from repro.models.model import LanguageModel
        from repro.models.transformer import Dist

        mesh = make_mesh((2, 2), ("data", "model"))
        cfg = get_config("gemma2_27b", smoke=True)
        lm = LanguageModel(cfg, tp=2)
        params, _ = lm.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)

        def logits_with(seq_shard):
            c = cfg.replace(seq_shard=seq_shard)
            l2 = LanguageModel(c, tp=2)
            dist = Dist(mesh=mesh, data_axes=("data",), model_axis="model", tp=2)
            with mesh:
                out, _ = jax.jit(lambda p, b: l2.forward(p, b, dist))(
                    params, {"tokens": toks})
            return np.asarray(out, np.float32)

        a = logits_with(False)
        b = logits_with(True)
        err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        # bf16 forward: resharding the residual stream reorders every
        # layer's reductions; 2.2e-2 relative-to-max is the deterministic
        # skew on this stack, so the bound is 3e-2 (a real wiring bug is
        # orders of magnitude larger).
        assert err < 3e-2, err
        print("SEQ_SHARD_OK", err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=1800)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    assert "SEQ_SHARD_OK" in out.stdout

"""Serving-path chaos: the no-hung-ticket invariant under injected faults.

Four layers:

  * CRASH ISOLATION drills: the ``serve.launch`` / ``serve.stream`` /
    ``serve.stall`` injection points (``repro.faults``) kill the batch
    launch, the mid-stream delivery, and the scheduler's policy step —
    transient faults retry (only the still-unresolved rows), poisoned
    batches fail their own tickets with the typed error, a dead scheduler
    fail-fasts everything via the watchdog;
  * ESTIMATOR guards: faulted/retried/degraded batches must not poison the
    EWMA service-time estimate, and a clean outlier sample is clamped;
  * the SEEDED CHAOS SWEEP: every ``serve.*`` point armed in turn (once
    and sticky, fire count derived from ``REPRO_FAULT_SEED``) under live
    threaded traffic with shedding and cancellation mixed in — 100% of
    submitted tickets must resolve with a result, a typed error, or a
    cancellation;
  * DEVICE-LOSS degraded serving: a device dies mid-traffic under a
    ``KNNServer`` fronting the mutable dynamic forest — answers stay
    exact from the survivors, degradation lands in ``Ticket.info`` and
    ``server.reasons`` (subprocess drill forcing 4 host devices, plus an
    in-process variant behind the ``multi_device`` skip for the ci.sh
    chaos leg).
"""

import os
import subprocess
import sys
import textwrap
import types

import numpy as np
import pytest

from repro import faults
from repro.api import IndexSpec, KNNIndex, knn_brute
from repro.serving.knn_server import (
    KNNServer,
    Overloaded,
    SchedulerDied,
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
SEED = int(os.environ.get("REPRO_FAULT_SEED", "0"))

N, D, K = 4000, 8, 10


def _device_count() -> int:
    import jax

    return jax.device_count()


multi_device = pytest.mark.skipif(
    _device_count() < 4,
    reason="needs >= 4 devices (ci.sh chaos gate forces 4 host devices)",
)


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.reset()
    yield
    faults.reset()


@pytest.fixture(scope="module")
def index():
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(N, D)).astype(np.float32)
    idx = KNNIndex.build(
        pts, spec=IndexSpec(engine="streaming", height=4, k_hint=K)
    )
    return pts, idx


def _queries(m, seed=1):
    return np.random.default_rng(seed).normal(size=(m, D)).astype(np.float32)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class _StubIndex:
    engine_name = "streaming"
    d = D
    spec = types.SimpleNamespace(k_hint=K)

    def __init__(self, behavior):
        self._behavior = behavior

    def warm(self, m, k):
        pass

    def query_stream(self, qs, k, *, on_complete):
        return self._behavior(qs, k, on_complete)


def _stub_serve_all(qs, k, emit):
    m = qs.shape[0]
    emit(np.arange(m), np.zeros((m, k), np.float32),
         np.zeros((m, k), np.int64))
    return types.SimpleNamespace(stats=types.SimpleNamespace(events=()))


def _policy_server(idx, **kw):
    kw.setdefault("clock", FakeClock())
    kw.setdefault("start", False)
    kw.setdefault("retry_backoff_s", 0.0)
    kw.setdefault("sleep", lambda s: None)
    return KNNServer(idx, k=K, max_batch=32, **kw)


class TestCrashIsolation:
    def test_transient_launch_fault_retries_and_serves(self, index):
        pts, idx = index
        srv = _policy_server(idx)
        q = _queries(4, seed=3)
        tickets = srv.submit_many(q, deadline_ms=10_000.0)
        faults.arm("serve.launch", after=1)          # one transient blip
        assert srv.pump_once(force=True) == 4
        bd, _ = knn_brute(q, pts, K)
        for r, t in enumerate(tickets):
            d, _i = t.result(timeout=0)
            np.testing.assert_allclose(d, bd[r], rtol=1e-4, atol=1e-4)
        stats = srv.stats()
        assert stats["retries"] == 1 and stats["failed"] == 0
        assert any("attempt 1 failed" in r and "retrying 4 request(s)" in r
                   for r in srv.reasons)
        srv.close()

    def test_sticky_launch_fault_fails_batch_not_server(self, index):
        pts, idx = index
        srv = _policy_server(idx)
        tickets = srv.submit_many(_queries(3, seed=4), deadline_ms=10_000.0)
        faults.arm("serve.launch", sticky=True)
        assert srv.pump_once(force=True) == 3
        for t in tickets:
            exc = t.exception(timeout=0)
            assert isinstance(exc, faults.FaultError)
            assert t.info["error"] == "FaultError"
            with pytest.raises(faults.FaultError):
                t.result(timeout=0)
        stats = srv.stats()
        assert stats["failed"] == 3 and stats["outstanding"] == 0
        assert stats["retries"] == srv.batch_retries
        assert any("FAILED after 3 attempt(s)" in r for r in srv.reasons)
        # the scheduler survived: disarm and the next batch serves
        faults.reset()
        t = srv.submit(_queries(1, seed=5)[0], deadline_ms=10_000.0)
        assert srv.pump_once(force=True) == 1
        assert t.exception(timeout=0) is None
        srv.close()

    def test_mid_stream_fault_retries_unresolved_rows(self, index):
        # a real streaming batch dies at its FIRST delivery: nothing is
        # resolved, the retry re-runs the engine (proving an aborted
        # stream leaves it usable) and parity holds end-to-end
        pts, idx = index
        srv = _policy_server(idx)
        q = _queries(8, seed=6)
        tickets = srv.submit_many(q, deadline_ms=10_000.0)
        faults.arm("serve.stream", after=1)
        assert srv.pump_once(force=True) == 8
        bd, _ = knn_brute(q, pts, K)
        for r, t in enumerate(tickets):
            d, _i = t.result(timeout=0)
            np.testing.assert_allclose(d, bd[r], rtol=1e-4, atol=1e-4)
        assert srv.stats()["retries"] >= 1
        srv.close()

    def test_partial_delivery_retries_only_remainder(self):
        # two-chunk stub stream: chunk 1 resolves rows 0-3, the second
        # delivery faults — the retry must re-serve ONLY the 4 unresolved
        # rows (the stub always sees the zero-padded 32-bucket; what
        # matters is which tickets were already done at re-entry)
        tickets: list = []
        done_at_entry: list = []

        def behavior(qs, k, emit):
            done_at_entry.append([t.done() for t in tickets])
            emit(np.arange(4), np.full((4, k), 1.0, np.float32),
                 np.zeros((4, k), np.int64))
            m = qs.shape[0]
            emit(np.arange(4, m), np.full((m - 4, k), 2.0, np.float32),
                 np.zeros((m - 4, k), np.int64))
            return types.SimpleNamespace(
                stats=types.SimpleNamespace(events=())
            )

        srv = _policy_server(_StubIndex(behavior))
        tickets.extend(
            srv.submit(np.zeros(D), deadline_ms=10_000.0) for _ in range(8)
        )
        faults.arm("serve.stream", after=2)   # second delivery dies
        assert srv.pump_once(force=True) == 8
        assert all(t.done() for t in tickets)
        assert all(t.exception(timeout=0) is None for t in tickets)
        # attempt 1 entered with nothing resolved; the retry entered with
        # exactly rows 0-3 already resolved and only served the remainder
        assert done_at_entry[0] == [False] * 8
        assert done_at_entry[1] == [True] * 4 + [False] * 4
        # the retry's chunk-1 rows map to tickets 4-7: value 1.0, not 2.0
        assert all(
            float(t.result(timeout=0)[0][0]) == 1.0 for t in tickets[4:]
        )
        stats = srv.stats()
        assert stats["completed"] == 8 and stats["retries"] == 1
        srv.close()

    def test_raising_engine_resolves_tickets_not_hangs(self):
        # regression (satellite): an engine exception used to kill the
        # scheduler thread silently, stranding every Ticket forever
        broken = {"on": True}

        def behavior(qs, k, emit):
            if broken["on"]:
                raise ValueError("engine exploded")
            return _stub_serve_all(qs, k, emit)

        with KNNServer(_StubIndex(behavior), k=K, max_batch=32,
                       default_deadline_ms=30.0,
                       retry_backoff_s=0.001) as srv:
            t = srv.submit(np.zeros(D))
            exc = t.exception(timeout=30.0)      # must NOT hang
            assert isinstance(exc, ValueError)   # non-transient: no retry
            assert srv.stats()["retries"] == 0
            # one poisoned batch does not kill the loop
            broken["on"] = False
            t2 = srv.submit(np.ones(D))
            assert t2.exception(timeout=30.0) is None
            stats = srv.stats()
            assert stats["failed"] == 1 and stats["completed"] == 1
            assert not stats["dead"]

    def test_scheduler_stall_watchdog_fail_fasts(self, index):
        _, idx = index
        srv = _policy_server(idx)
        tickets = srv.submit_many(_queries(3, seed=7), deadline_ms=10_000.0)
        faults.arm("serve.stall")
        with pytest.raises(faults.FaultError):
            srv.pump_once(force=True)
        for t in tickets:
            assert isinstance(t.exception(timeout=0), SchedulerDied)
        stats = srv.stats()
        assert stats["dead"] and stats["outstanding"] == 0
        assert any(r.startswith("watchdog: scheduler died")
                   for r in srv.reasons)
        with pytest.raises(SchedulerDied):
            srv.submit(_queries(1)[0])
        with pytest.raises(SchedulerDied):
            srv.pump_once()
        srv.close()                              # must not hang

    def test_scheduler_stall_threaded_watchdog(self, index):
        _, idx = index
        faults.arm("serve.stall", sticky=True)
        with KNNServer(idx, k=K, max_batch=32,
                       default_deadline_ms=30.0) as srv:
            t = srv.submit(_queries(1, seed=8)[0])
            exc = t.exception(timeout=30.0)      # watchdog, not a hang
            assert isinstance(exc, SchedulerDied)
            assert srv.stats()["dead"]
            with pytest.raises(SchedulerDied):
                srv.submit(_queries(1)[0])


class TestEstimatorGuards:
    def test_faulted_batch_never_feeds_estimate(self):
        clock = FakeClock()
        calls = {"n": 0}

        def behavior(qs, k, emit):
            calls["n"] += 1
            clock.advance(10.0)          # an incident-sized wall time
            if calls["n"] == 1:
                raise faults.FaultError("transient blip")
            return _stub_serve_all(qs, k, emit)

        srv = _policy_server(_StubIndex(behavior), clock=clock)
        srv.submit(np.zeros(D), deadline_ms=1e9)
        assert srv.pump_once(force=True) == 1
        # seeded 20ms estimate survives the 10s faulted/retried batch
        assert srv.stats()["est_service_ms"][32] == pytest.approx(20.0)
        assert any("SKIPPED" in r for r in srv.reasons)
        srv.close()

    def test_clean_outlier_sample_is_clamped(self):
        clock = FakeClock()

        def behavior(qs, k, emit):
            clock.advance(10.0)          # 500x the 20ms estimate
            return _stub_serve_all(qs, k, emit)

        srv = _policy_server(_StubIndex(behavior), clock=clock)
        srv.submit(np.zeros(D), deadline_ms=1e9)
        assert srv.pump_once(force=True) == 1
        # EWMA absorbs at most 8x the prior estimate:
        # 0.6*20ms + 0.4*160ms = 76ms, not 0.6*20ms + 0.4*10000ms
        assert srv.stats()["est_service_ms"][32] == pytest.approx(76.0)
        assert any("clamped" in r for r in srv.reasons)
        srv.close()

    def test_aborted_stream_leaves_index_usable(self, index):
        # emit raising aborts the round loop mid-stream; the engine must
        # come back exact on the next query (the retry path depends on it)
        pts, idx = index
        q = _queries(8, seed=9)

        def bad_emit(rows, dists, ix):
            raise RuntimeError("consumer exploded")

        with pytest.raises(RuntimeError, match="consumer exploded"):
            idx.query_stream(q, K, on_complete=bad_emit)
        d, _i = idx.query(q, k=K)
        bd, _ = knn_brute(q, pts, K)
        np.testing.assert_allclose(d, bd, rtol=1e-4, atol=1e-4)


class TestServeChaosSweep:
    """Every serve.* point armed in turn under live threaded traffic.

    The invariant being proven: 100% of submitted tickets RESOLVE — a
    result, a typed error, or a cancellation; zero hangs.  Fire counts
    and deadlines derive from REPRO_FAULT_SEED (ci.sh sweeps it), so CI
    keeps exploring new interleavings deterministically.
    """

    @pytest.mark.parametrize("sticky", [False, True])
    @pytest.mark.parametrize(
        "point", ["serve.launch", "serve.stream", "serve.stall"]
    )
    def test_no_ticket_ever_hangs(self, index, point, sticky):
        _, idx = index
        case = faults.INJECTION_POINTS.index(point) * 2 + int(sticky)
        rng = np.random.default_rng([SEED, case])
        nreq = 40
        queries = rng.normal(size=(nreq, D)).astype(np.float32)
        faults.arm(point, after=int(rng.integers(1, 6)), sticky=sticky)
        srv = KNNServer(
            idx, k=K, max_batch=32, max_queue=16,
            default_deadline_ms=float(rng.choice([15.0, 60.0])),
            retry_backoff_s=0.001,
        )
        submitted, shed = [], 0
        for i in range(nreq):
            try:
                t = srv.submit(queries[i])
            except Overloaded:
                shed += 1
                continue
            except SchedulerDied:
                break
            submitted.append(t)
            if rng.random() < 0.1:
                t.cancel()
        for t in submitted:
            # TimeoutError here IS the invariant violation (a hung ticket)
            t.exception(timeout=60.0)
        assert all(t.done() for t in submitted)
        stats = srv.stats()
        assert stats["outstanding"] == 0
        resolved = (stats["completed"] + stats["failed"] + stats["purged"]
                    + stats["cancelled"])
        assert resolved == len(submitted)
        assert shed + len(submitted) <= nreq
        srv.close()


def _degraded_serving_script(threaded: bool) -> str:
    return textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        import jax
        jax.config.update("jax_cpu_enable_async_dispatch", False)
        from repro import faults
        from repro.api import IndexSpec, KNNIndex, knn_brute
        from repro.serving.knn_server import KNNServer

        rng = np.random.default_rng(0)
        d, k = 5, 5
        pts = rng.normal(size=(12288, d)).astype(np.float32)
        idx = KNNIndex.build(
            pts[:8192],
            spec=IndexSpec(mutable=True, buffer_size=1024, k_hint=k),
        )
        for lo in range(8192, 12288, 1024):
            idx.insert(pts[lo:lo + 1024])
        idx.drain(timeout=120)
        st = idx._state
        devs = jax.devices()
        victims = [
            i for i, dev in enumerate(devs)
            if any(s.device is dev for s in st._shards)
        ]
        assert len({{str(s.device) for s in st._shards}}) >= 2
        victim = victims[-1]

        srv = KNNServer(
            idx, k=k, max_batch=32,
            default_deadline_ms={250.0 if threaded else 10_000.0},
            start={threaded},
        )
        q = rng.normal(size=(16, d)).astype(np.float32)

        # warm serving round trip BEFORE the loss
        t0 = srv.submit(q[0]);
        if not {threaded}: srv.pump_once(force=True)
        t0.result(timeout=120.0)

        faults.arm("device.scan", device_index=victim, sticky=True)
        tickets = [srv.submit(row) for row in q]
        if not {threaded}:
            srv.pump_once(force=True)
        srv.drain(timeout=120.0)
        faults.reset()

        bd, _ = knn_brute(q, pts, k)
        for r, t in enumerate(tickets):
            dd, di = t.result(timeout=0.1)
            assert np.allclose(dd, bd[r], rtol=1e-4, atol=1e-4), (
                "degraded serving != exact"
            )
            ev = t.info.get("degraded")
            assert ev and any("device loss" in e for e in ev), t.info
        assert any("degraded" in r and "device loss" in r
                   for r in srv.reasons)
        assert srv.stats()["degraded_batches"] >= 1
        assert not any(s.device is devs[victim] for s in st._shards)

        # the shrunken fan-out keeps serving
        t2 = srv.submit(q[0])
        if not {threaded}: srv.pump_once(force=True)
        dd, _ = t2.result(timeout=120.0)
        assert np.allclose(dd, bd[0], rtol=1e-4, atol=1e-4)
        assert "degraded" not in t2.info
        srv.close()
        print("DEGRADED_SERVING_OK")
    """)


def test_device_loss_degraded_serving_subprocess():
    """Tier-1 acceptance drill: a shard-bearing device dies mid-traffic
    under a KNNServer fronting the mutable forest — tickets keep resolving
    with exact survivor-side answers, degradation lands in Ticket.info and
    server.reasons, and the server keeps serving afterwards."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _degraded_serving_script(threaded=False)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    assert "DEGRADED_SERVING_OK" in out.stdout


@multi_device
def test_device_loss_degraded_serving_threaded_inprocess():
    """In-process threaded variant for the ci.sh chaos leg (4 forced host
    devices): the live scheduler thread, not pump_once, rides through the
    device loss.  The script's env/config lines are no-ops in-process
    (devices are already forced by the leg's XLA_FLAGS)."""
    exec(compile(_degraded_serving_script(threaded=True),
                 "<degraded-serving>", "exec"), {})

"""KNNServer: admission queue, rung-bucket batching, SLA-aware close.

The scheduling policy is tested DETERMINISTICALLY: ``start=False`` servers
driven by ``pump_once()`` with an injected fake clock, so deadline math is
exact and no test sleeps to coax the scheduler.  A threaded server covers
the end-to-end path (out-of-order ticket resolution, parity vs brute, the
queue-starvation regression).
"""

import types

import numpy as np
import pytest

from repro.api import IndexSpec, KNNIndex, StreamingUnsupported, knn_brute
from repro.serving.knn_server import (
    Cancelled,
    DeadlineExceeded,
    KNNServer,
    Overloaded,
)

N, D, K = 4000, 8, 10


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def index():
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(N, D)).astype(np.float32)
    idx = KNNIndex.build(
        pts, spec=IndexSpec(engine="streaming", height=4, k_hint=K)
    )
    return pts, idx


def _queries(m, seed=1):
    return np.random.default_rng(seed).normal(size=(m, D)).astype(np.float32)


class TestBatchClosePolicy:
    def test_rung_full_close(self, index):
        pts, idx = index
        clock = FakeClock()
        srv = KNNServer(idx, k=K, max_batch=32, clock=clock, start=False)
        q = _queries(32)
        tickets = srv.submit_many(q, deadline_ms=10_000.0)
        served = srv.pump_once()
        assert served == 32
        assert " close=rung_full " in srv.reasons[-1]
        bd, _ = knn_brute(q, pts, K)
        for r, t in enumerate(tickets):
            assert t.done()
            d, i = t.result(timeout=0)
            np.testing.assert_allclose(d, bd[r], rtol=1e-4, atol=1e-4)
        srv.close()

    def test_deadline_forces_short_batch(self, index):
        _, idx = index
        clock = FakeClock()
        srv = KNNServer(idx, k=K, max_batch=32, clock=clock, start=False)
        t = srv.submit(_queries(1)[0], deadline_ms=30.0)
        # slack = 30ms deadline - 20ms default estimate = 10ms: policy must
        # HOLD the batch open while slack remains...
        assert srv.pump_once() == 0
        clock.advance(0.005)
        assert srv.pump_once() == 0
        # ...and close the moment it runs out, well before the rung fills
        clock.advance(0.006)
        assert srv.pump_once() == 1
        assert t.done()
        reason = srv.reasons[-1]
        assert " close=deadline " in reason and "size=1/32" in reason
        assert "slack_ms=" in reason and "est_service_ms=" in reason
        srv.close()

    def test_bucket_is_smallest_rung_that_fits(self, index):
        _, idx = index
        clock = FakeClock()
        srv = KNNServer(idx, k=K, max_batch=64, clock=clock, start=False)
        assert srv.buckets == (32, 64)      # compaction ladder of 64
        # deadline-close fires once slack (deadline - now - est) runs out,
        # BEFORE the deadline itself — no request gets purged here
        srv.submit_many(_queries(40), deadline_ms=1000.0)
        clock.advance(0.99)
        assert srv.pump_once() == 40
        assert "size=40/64" in srv.reasons[-1]
        stats = srv.stats()
        assert stats["batches_by_close"] == {"deadline": 1}
        srv.close()

    def test_seeded_trace_replay_is_deterministic(self, index):
        pts, idx = index
        # same arrival trace + same pump ticks => identical close decisions
        rng = np.random.default_rng(42)
        arrivals = np.cumsum(rng.exponential(0.004, size=24))
        queries = _queries(24, seed=42)
        deadlines = rng.choice([25.0, 60.0], size=24)

        def replay():
            clock = FakeClock()
            srv = KNNServer(idx, k=K, max_batch=32, clock=clock, start=False)
            results, log = {}, []
            next_req = 0
            for tick in np.arange(0.0, 0.25, 0.002):
                clock.t = float(tick)
                while next_req < 24 and arrivals[next_req] <= tick:
                    results[next_req] = srv.submit(
                        queries[next_req], deadline_ms=float(deadlines[next_req])
                    )
                    next_req += 1
                if srv.pump_once():
                    log.append(srv.reasons[-1])
            while srv.pump_once(force=True):
                log.append(srv.reasons[-1])
            srv.close()
            return log, {r: t.result(timeout=0) for r, t in results.items()}

        log_a, res_a = replay()
        log_b, res_b = replay()
        assert log_a == log_b and len(log_a) > 1
        bd, _ = knn_brute(queries, pts, K)
        for r in range(24):
            np.testing.assert_array_equal(res_a[r][1], res_b[r][1])
            np.testing.assert_allclose(res_a[r][0], bd[r], rtol=1e-4, atol=1e-4)


class _StubIndex:
    """Minimal index standing in for engine behavior tests: registered
    engine name (so the caps gate passes), injectable ``query_stream``."""

    engine_name = "streaming"
    d = D
    spec = types.SimpleNamespace(k_hint=K)

    def __init__(self, behavior):
        self._behavior = behavior

    def warm(self, m, k):
        pass

    def query_stream(self, qs, k, *, on_complete):
        return self._behavior(qs, k, on_complete)


def _stub_serve_all(qs, k, emit):
    m = qs.shape[0]
    emit(np.arange(m), np.zeros((m, k), np.float32),
         np.zeros((m, k), np.int64))
    return types.SimpleNamespace(stats=types.SimpleNamespace(events=()))


class TestAdmissionControl:
    def test_queue_full_sheds_at_exact_max_queue(self, index):
        _, idx = index
        clock = FakeClock()
        srv = KNNServer(idx, k=K, max_batch=32, max_queue=4, clock=clock,
                        start=False)
        tickets = [srv.submit(q, deadline_ms=10_000.0)
                   for q in _queries(4)]
        with pytest.raises(Overloaded) as ei:
            srv.submit(_queries(1)[0], deadline_ms=10_000.0)
        assert ei.value.queue_depth == 4
        assert ei.value.est_wait_s > 0.0
        assert srv.reasons[-1] == (
            "shed: queue full (4/4); est_wait_ms=20.00"
        )
        assert srv.stats()["shed"] == 1
        # serving the backlog reopens admission
        assert srv.pump_once(force=True) == 4
        assert all(t.done() for t in tickets)
        t = srv.submit(_queries(1)[0], deadline_ms=10_000.0)
        assert not t.done()
        srv.close()

    def test_purge_expired_oldest_first(self, index):
        _, idx = index
        clock = FakeClock()
        srv = KNNServer(idx, k=K, max_batch=32, clock=clock, start=False)
        ta = srv.submit(_queries(1)[0], deadline_ms=10.0)    # rid 0
        tb = srv.submit(_queries(1)[0], deadline_ms=5.0)     # rid 1
        tc = srv.submit(_queries(1)[0], deadline_ms=10_000.0)
        clock.advance(0.02)
        srv.pump_once()
        # both expired requests fail typed, most-late (tb) purged first
        purges = [r for r in srv.reasons if r.startswith("purge ")]
        assert purges == [
            "purge rid=1: deadline exceeded 15.00ms before launch",
            "purge rid=0: deadline exceeded 10.00ms before launch",
        ]
        for t, late in ((ta, 0.010), (tb, 0.015)):
            exc = t.exception(timeout=0)
            assert isinstance(exc, DeadlineExceeded)
            assert exc.rid == t.rid
            assert exc.late_s == pytest.approx(late)
            with pytest.raises(DeadlineExceeded):
                t.result(timeout=0)
        assert not tc.done()            # unexpired request still queued
        assert srv.stats()["purged"] == 2
        assert srv.stats()["outstanding"] == 1
        srv.drain()
        assert tc.done() and tc.exception(timeout=0) is None
        srv.close()

    def test_purge_can_be_disabled(self, index):
        _, idx = index
        clock = FakeClock()
        srv = KNNServer(idx, k=K, max_batch=32, clock=clock, start=False,
                        purge_expired=False)
        t = srv.submit(_queries(1)[0], deadline_ms=1.0)
        clock.advance(5.0)
        assert srv.pump_once() == 1     # served late instead of purged
        d, _ = t.result(timeout=0)
        assert d.shape == (K,)
        assert srv.stats()["purged"] == 0
        srv.close()

    def test_trace_replay_pins_reason_strings(self, index):
        _, idx = index

        def replay():
            clock = FakeClock()
            srv = KNNServer(idx, k=K, max_batch=32, max_queue=2,
                            clock=clock, start=False)
            srv.submit(_queries(1)[0], deadline_ms=10.0)         # rid 0
            srv.submit(_queries(1)[0], deadline_ms=5.0)          # rid 1
            with pytest.raises(Overloaded):
                srv.submit(_queries(1)[0], deadline_ms=5.0)      # shed
            clock.advance(0.02)
            assert srv.pump_once() == 0                          # purges
            srv.submit(_queries(1)[0], deadline_ms=10_000.0)     # rid 2
            t3 = srv.submit(_queries(1)[0], deadline_ms=10_000.0)
            assert t3.cancel()
            assert srv.pump_once(force=True) == 1
            reasons = srv.reasons
            srv.close()
            return reasons

        expected_tail = [
            "shed: queue full (2/2); est_wait_ms=20.00",
            "purge rid=1: deadline exceeded 15.00ms before launch",
            "purge rid=0: deadline exceeded 10.00ms before launch",
            "cancel rid=3: before launch",
            "batch 0: close=drain size=1/32",
        ]
        a, b = replay(), replay()
        assert a == b
        assert list(a[-5:]) == expected_tail


class TestTicketLifecycle:
    def test_cancel_before_launch(self, index):
        _, idx = index
        clock = FakeClock()
        srv = KNNServer(idx, k=K, max_batch=32, clock=clock, start=False)
        t0 = srv.submit(_queries(1)[0], deadline_ms=10_000.0)
        t1 = srv.submit(_queries(1)[0], deadline_ms=10_000.0)
        assert t0.cancel() is True
        assert t0.cancel() is False             # already resolved
        assert t0.cancelled() and t0.done()
        assert isinstance(t0.exception(timeout=0), Cancelled)
        with pytest.raises(Cancelled):
            t0.result(timeout=0)
        # the cancelled request never occupies a batch slot
        assert srv.pump_once(force=True) == 1
        assert t1.done() and t1.exception(timeout=0) is None
        stats = srv.stats()
        assert stats["cancelled"] == 1 and stats["completed"] == 1
        assert stats["outstanding"] == 0
        assert "cancel rid=0: before launch" in srv.reasons
        srv.close()

    def test_cancel_mid_batch_discards_result(self):
        holder = {}

        def behavior(qs, k, emit):
            holder["t0"].cancel()       # races the in-flight batch
            return _stub_serve_all(qs, k, emit)

        srv = KNNServer(_StubIndex(behavior), k=K, max_batch=32,
                        clock=FakeClock(), start=False)
        holder["t0"] = srv.submit(np.zeros(D), deadline_ms=10_000.0)
        t1 = srv.submit(np.ones(D), deadline_ms=10_000.0)
        assert srv.pump_once(force=True) == 2   # both taken into the batch
        assert holder["t0"].cancelled()
        with pytest.raises(Cancelled):
            holder["t0"].result(timeout=0)
        assert t1.exception(timeout=0) is None
        stats = srv.stats()
        assert stats["cancelled"] == 1 and stats["completed"] == 1
        assert stats["outstanding"] == 0
        assert ("cancel rid=0: mid-batch; in-flight result will be "
                "discarded") in srv.reasons
        srv.close()

    def test_exception_returns_none_for_success(self, index):
        _, idx = index
        srv = KNNServer(idx, k=K, max_batch=32, clock=FakeClock(),
                        start=False)
        t = srv.submit(_queries(1)[0], deadline_ms=10_000.0)
        with pytest.raises(TimeoutError):
            t.exception(timeout=0)      # unresolved: blocks, then raises
        srv.pump_once(force=True)
        assert t.exception(timeout=0) is None
        srv.close()


class TestThreadedServer:
    def test_out_of_order_completion_parity(self, index):
        pts, idx = index
        q = _queries(100, seed=9)
        # purge_expired=False: this test measures parity of LATE
        # completions under a deliberately tight deadline
        with KNNServer(idx, k=K, max_batch=32, default_deadline_ms=20.0,
                       purge_expired=False) as srv:
            tickets = srv.submit_many(q)
            pairs = [t.result(timeout=60.0) for t in tickets]
            stats = srv.stats()
        bd, bi = knn_brute(q, pts, K)
        d = np.stack([p[0] for p in pairs])
        i = np.stack([p[1] for p in pairs])
        np.testing.assert_allclose(d, bd, rtol=1e-4, atol=1e-4)
        assert (i == bi).mean() > 0.99
        assert stats["completed"] == 100 and stats["outstanding"] == 0
        # 100 requests through a 32-rung server cannot fit one batch
        assert stats["batches"] >= 4

    def test_single_request_never_starves(self, index):
        # regression: one request and NO follow-up traffic must still be
        # served once its slack expires — the scheduler may not wait for
        # the rung to fill
        _, idx = index
        with KNNServer(idx, k=K, max_batch=256,
                       default_deadline_ms=250.0) as srv:
            t = srv.submit(_queries(1, seed=13)[0])
            d, i = t.result(timeout=30.0)
        assert d.shape == (K,) and i.shape == (K,)
        assert t.info["shape"] == 32       # smallest rung, not 256
        assert " close=" in t.info["reason"]

    def test_ticket_info_records_serving_metadata(self, index):
        _, idx = index
        with KNNServer(idx, k=K, max_batch=32,
                       default_deadline_ms=150.0) as srv:
            t = srv.submit(_queries(1, seed=17)[0])
            t.result(timeout=30.0)
        assert t.info["latency_s"] >= t.info["wait_s"] >= 0.0
        assert t.info["batch"] == 0


class TestValidationAndLifecycle:
    def test_non_streaming_index_rejected(self, index):
        pts, _ = index
        chunked = KNNIndex.build(pts, spec=IndexSpec(engine="chunked",
                                                     height=4, k_hint=K))
        with pytest.raises(StreamingUnsupported, match="streaming"):
            KNNServer(chunked, k=K)

    def test_submit_validation(self, index):
        _, idx = index
        srv = KNNServer(idx, k=K, max_batch=32, start=False)
        with pytest.raises(ValueError, match="dim"):
            srv.submit(np.zeros(D + 1, np.float32))
        with pytest.raises(ValueError, match="exceeds"):
            srv.submit(np.zeros(D, np.float32), k=K + 1)
        srv.close()
        with pytest.raises(RuntimeError, match="closed"):
            srv.submit(np.zeros(D, np.float32))

    def test_drain_serves_everything_queued(self, index):
        _, idx = index
        clock = FakeClock()
        srv = KNNServer(idx, k=K, max_batch=32, clock=clock, start=False)
        tickets = srv.submit_many(_queries(5, seed=23), deadline_ms=10_000.0)
        srv.drain()
        assert all(t.done() for t in tickets)
        assert " close=drain " in srv.reasons[-1]
        srv.close()

    def test_estimate_seeded_from_calibration(self, index):
        _, idx = index

        class Cal:
            round_s = 0.004
            source = "test-cal"

        srv = KNNServer(idx, k=K, max_batch=32, calibration=Cal(),
                        start=False)
        # 4ms round x 8 round guess = 32ms seed
        assert srv.stats()["est_service_ms"][32] == pytest.approx(32.0)
        assert any("test-cal" in r for r in srv.reasons)
        srv.close()

"""Fault-injection registry + the failure drills it powers.

Three layers:

  * UNIT tests of ``repro.faults`` itself (arming, after-counts, sticky,
    ctx matching, env parsing) — the registry must be trustworthy before
    any chaos result built on it means anything;
  * MERGE-FAILURE drills: the background carry merge's bounded-backoff
    retry contract, driven through the registry's ``merge.build`` /
    ``merge.swap`` points (the ``_merge_test_hook`` variants live in
    ``test_dynamic.py``; here the production injection sites are used);
  * DEVICE-LOSS drills: a subprocess acceptance test (tier-1, forces 4
    virtual host devices) asserting queries DEGRADE — exact answers from
    the survivors, a re-placement event in ``SearchStats.events`` and
    ``Plan.reasons``, no raise — plus in-process variants behind the
    ``multi_device`` skip (exercised by ``scripts/ci.sh``'s chaos gate).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import faults

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _device_count() -> int:
    import jax

    return jax.device_count()


multi_device = pytest.mark.skipif(
    _device_count() < 4,
    reason="needs >= 4 devices (ci.sh chaos gate forces 4 host devices)",
)


@pytest.fixture(autouse=True)
def _clean_registry():
    faults.reset()
    yield
    faults.reset()


# ---------------------------------------------------------------------------
# registry units
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_disarmed_fire_is_a_noop(self):
        for point in faults.INJECTION_POINTS:
            faults.fire(point)  # must not raise

    def test_unknown_point_refused_at_arm_time(self):
        with pytest.raises(ValueError, match="unknown injection point"):
            faults.arm("wal.tron")

    def test_fires_on_nth_hit_then_disarms(self):
        faults.arm("wal.append", after=3)
        faults.fire("wal.append")
        faults.fire("wal.append")
        with pytest.raises(faults.SimulatedCrash):
            faults.fire("wal.append")
        faults.fire("wal.append")  # non-sticky: disarmed after firing

    def test_sticky_keeps_firing(self):
        faults.arm("merge.build", sticky=True)
        for _ in range(3):
            with pytest.raises(faults.FaultError):
                faults.fire("merge.build")

    def test_ctx_match_filters_hits(self):
        faults.arm("device.scan", device_index=2)
        faults.fire("device.scan", device_index=0)
        faults.fire("device.scan", device_index=1)
        faults.fire("device.scan")          # missing key: no match
        with pytest.raises(faults.DeviceLost) as ei:
            faults.fire("device.scan", device_index=2, device="cpu:2")
        assert ei.value.device == "cpu:2"
        assert ei.value.device_index == 2

    def test_default_exception_types_by_prefix(self):
        cases = {
            "wal.torn": faults.SimulatedCrash,
            "persist.commit": faults.SimulatedCrash,
            "checkpoint.write": faults.SimulatedCrash,
            "merge.swap": faults.FaultError,
            "device.scan": faults.DeviceLost,
        }
        for point, exc_type in cases.items():
            faults.arm(point)
            with pytest.raises(exc_type):
                faults.fire(point)

    def test_explicit_exception_override(self):
        boom = KeyError("custom")
        faults.arm("merge.build", exc=boom)
        with pytest.raises(KeyError):
            faults.fire("merge.build")

    def test_hit_counting_enumerates_boundaries(self):
        faults.count_hits()
        faults.fire("wal.append")
        faults.fire("wal.append")
        faults.fire("persist.commit")
        assert faults.hits("wal.append") == 2
        assert faults.hits("persist.commit") == 1
        assert faults.hits("wal.torn") == 0

    def test_env_spec_parsing(self):
        # load_env is idempotent-by-flag; drive the parser via a subprocess
        script = textwrap.dedent("""
            import os
            os.environ["REPRO_FAULTS"] = "wal.torn:2,device.scan:1:sticky"
            from repro import faults
            faults.load_env()
            faults.fire("wal.torn")
            try:
                faults.fire("wal.torn")
                raise SystemExit("wal.torn never fired")
            except faults.SimulatedCrash:
                pass
            for _ in range(2):
                try:
                    faults.fire("device.scan")
                    raise SystemExit("device.scan not sticky")
                except faults.DeviceLost:
                    pass
            print("ENV_FAULTS_OK")
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        out = subprocess.run(
            [sys.executable, "-c", script], capture_output=True, text=True,
            env=env, timeout=120,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "ENV_FAULTS_OK" in out.stdout


# ---------------------------------------------------------------------------
# merge-failure drills through the production injection sites
# ---------------------------------------------------------------------------
D = 4
CFG = dict(base_capacity=16, tomb_limit=6, brute_cutoff=16)


def _apply_insert(idx, model, pts):
    for j, g in enumerate(idx.insert(pts)):
        model[int(g)] = pts[j]


def _check_parity(idx, model, q, k):
    from repro.core.brute import knn_brute

    ids = np.fromiter(sorted(model), np.int64, len(model))
    live = np.stack([model[int(g)] for g in ids])
    dd, di, _ = idx.query(q, k)
    bd, _ = knn_brute(q, live, k)
    np.testing.assert_allclose(dd, bd, rtol=1e-4, atol=1e-4)
    assert np.isin(di, ids).all()


class TestMergeFaults:
    def test_transient_build_fault_is_retried(self):
        from repro.core.dynamic import DynamicIndex

        rng = np.random.default_rng(7)
        idx = DynamicIndex(D, **CFG, merge_async=True)
        model = {}
        faults.arm("merge.build")   # one staging build dies
        _apply_insert(idx, model, rng.normal(size=(10, D)).astype(np.float32))
        _apply_insert(idx, model, rng.normal(size=(8, D)).astype(np.float32))
        idx.drain_merges(timeout=60)
        stats = idx.merge_stats()
        assert stats["failed"] == 1 and stats["retried"] >= 1
        assert stats["completed"] >= 1
        _check_parity(idx, model, rng.normal(size=(6, D)).astype(np.float32), 3)

    def test_swap_fault_is_retried(self):
        from repro.core.dynamic import DynamicIndex

        rng = np.random.default_rng(8)
        idx = DynamicIndex(D, **CFG, merge_async=True)
        model = {}
        faults.arm("merge.swap")    # dies AFTER the build, before the swap
        _apply_insert(idx, model, rng.normal(size=(10, D)).astype(np.float32))
        _apply_insert(idx, model, rng.normal(size=(8, D)).astype(np.float32))
        idx.drain_merges(timeout=60)
        assert idx.merge_stats()["completed"] >= 1
        assert not any(s.merging for s in idx._shards)
        _check_parity(idx, model, rng.normal(size=(6, D)).astype(np.float32), 3)

    def test_sticky_fault_exhausts_bounded_retries(self):
        from repro.core.dynamic import DynamicIndex, MERGE_MAX_RETRIES
        from repro.distributed.dynamic_shards import MergeRetryExhausted

        rng = np.random.default_rng(9)
        idx = DynamicIndex(D, **CFG, merge_async=True)
        model = {}
        faults.arm("merge.build", sticky=True)
        _apply_insert(idx, model, rng.normal(size=(10, D)).astype(np.float32))
        _apply_insert(idx, model, rng.normal(size=(8, D)).astype(np.float32))
        with pytest.raises(MergeRetryExhausted) as ei:
            idx.drain_merges(timeout=60)
        assert ei.value.rung == 0
        assert idx.merge_stats()["failed"] == MERGE_MAX_RETRIES + 1
        # exactness never depended on the merge landing
        _check_parity(idx, model, rng.normal(size=(4, D)).astype(np.float32), 3)

    def test_drain_timeout_names_the_stuck_rung(self):
        import threading

        from repro.core.dynamic import DynamicIndex
        from repro.distributed.dynamic_shards import DrainTimeout

        rng = np.random.default_rng(10)
        idx = DynamicIndex(D, **CFG, merge_async=True)
        release = threading.Event()

        def hook(phase, snaps):
            if phase == "build":
                assert release.wait(30)

        idx._merge_test_hook = hook
        model = {}
        _apply_insert(idx, model, rng.normal(size=(10, D)).astype(np.float32))
        _apply_insert(idx, model, rng.normal(size=(8, D)).astype(np.float32))
        try:
            with pytest.raises(DrainTimeout) as ei:
                idx.drain_merges(timeout=0.2)
            assert ei.value.rung == 0 and ei.value.rungs == (0,)
        finally:
            release.set()
            idx._merge_test_hook = None
        idx.drain_merges(timeout=60)   # the timeout bounded the WAIT only
        assert idx.merge_stats()["completed"] >= 1

    def test_facade_drain_timeout_passes_through(self):
        import threading

        from repro.api import IndexSpec, KNNIndex
        from repro.distributed.dynamic_shards import DrainTimeout

        rng = np.random.default_rng(11)
        pts = rng.normal(size=(64, D)).astype(np.float32)
        idx = KNNIndex.build(
            pts, spec=IndexSpec(mutable=True, buffer_size=32, merge_async=True)
        )
        release = threading.Event()

        def hook(phase, snaps):
            if phase == "build":
                assert release.wait(30)

        idx._state._merge_test_hook = hook
        try:
            idx.insert(rng.normal(size=(24, D)).astype(np.float32))
            idx.insert(rng.normal(size=(24, D)).astype(np.float32))
            with pytest.raises(DrainTimeout):
                idx.drain(timeout=0.2)
        finally:
            release.set()
            idx._state._merge_test_hook = None
        idx.drain(timeout=60)


# ---------------------------------------------------------------------------
# device-loss degradation
# ---------------------------------------------------------------------------
def test_device_loss_degrades_not_raises_subprocess():
    """Tier-1 acceptance drill: 4 forced host devices, a shard-bearing
    device dies mid-stream — queries keep answering exactly from the
    survivors, the re-placement reason lands in stats/plan, and later
    mutations proceed."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        import jax
        jax.config.update("jax_cpu_enable_async_dispatch", False)
        from repro import faults
        from repro.api import IndexSpec, KNNIndex, knn_brute

        rng = np.random.default_rng(0)
        d, k = 5, 5
        # rungs are tree-kind (device-spread) only above the planner's
        # brute cutoff (2048): build an 8192-cap rung, then carry-merge
        # four 1024 batches into a 4096-cap rung -> two tree rungs, two
        # devices
        pts = rng.normal(size=(12288, d)).astype(np.float32)
        idx = KNNIndex.build(
            pts[:8192],
            spec=IndexSpec(mutable=True, buffer_size=1024, k_hint=k),
        )
        model = {i: pts[i] for i in range(8192)}
        for lo in range(8192, 12288, 1024):
            b = pts[lo:lo + 1024]
            for j, g in enumerate(idx.insert(b)):
                model[int(g)] = b[j]
        idx.drain(timeout=120)
        st = idx._state
        devs = jax.devices()
        victims = [
            i for i, dev in enumerate(devs)
            if any(s.device is dev for s in st._shards)
        ]
        assert len({
            str(s.device) for s in st._shards
        }) >= 2, "forest never spread over devices"
        victim = victims[-1]

        faults.arm("device.scan", device_index=victim, sticky=True)
        q = rng.normal(size=(16, d)).astype(np.float32)
        dd, di = idx.query(q, k=k)           # must NOT raise
        faults.reset()

        ids = np.fromiter(sorted(model), np.int64, len(model))
        live = np.stack([model[int(g)] for g in ids])
        bd, _ = knn_brute(q, live, k)
        assert np.allclose(dd, bd, rtol=1e-4, atol=1e-4), "degraded != exact"
        assert np.isin(di, ids).all()

        ev = idx.stats.events
        assert len(ev) == 1 and "device loss" in ev[0], ev
        assert "re-placed" in ev[0] and "surviving device" in ev[0], ev
        assert any("device loss" in r for r in idx.plan.reasons)
        assert not any(s.device is devs[victim] for s in st._shards), (
            "victim still holds shards"
        )
        assert st.merge_stats()["device_loss"] == 1

        # the degraded index keeps mutating and answering exactly
        b = rng.normal(size=(150, d)).astype(np.float32)
        for j, g in enumerate(idx.insert(b)):
            model[int(g)] = b[j]
        idx.drain(timeout=120)
        ids = np.fromiter(sorted(model), np.int64, len(model))
        live = np.stack([model[int(g)] for g in ids])
        dd, di = idx.query(q, k=k)
        bd, _ = knn_brute(q, live, k)
        assert np.allclose(dd, bd, rtol=1e-4, atol=1e-4)
        print("DEVICE_LOSS_DEGRADE_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=1800,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    assert "DEVICE_LOSS_DEGRADE_OK" in out.stdout


@multi_device
class TestInProcessDeviceLoss:
    def test_placer_drop_device_contract(self):
        import jax

        from repro.distributed.dynamic_shards import ShardPlacer

        devs = jax.devices()[:4]
        placer = ShardPlacer(devs)
        placer.drop_device(devs[2])
        assert devs[2] not in placer.devices
        assert len(placer.devices) == 3
        with pytest.raises(KeyError):
            placer.drop_device(devs[2])
        for dev in (devs[0], devs[1]):
            placer.drop_device(dev)
        with pytest.raises(RuntimeError, match="last device"):
            placer.drop_device(devs[3])

    def test_handle_device_loss_moves_shards(self):
        import jax

        from repro.core.dynamic import DynamicIndex

        rng = np.random.default_rng(21)
        idx = DynamicIndex(
            D, base_capacity=32, brute_cutoff=32,
            devices=jax.devices()[:4], merge_async=False,
        )
        model = {}
        for _ in range(10):
            _apply_insert(
                idx, model, rng.normal(size=(200, D)).astype(np.float32)
            )
        victim = next(
            dev for dev in jax.devices()[:4][::-1]
            if any(s.device is dev for s in idx._shards)
        )
        event = idx.handle_device_loss(victim)
        assert "device loss" in event and "re-placed" in event
        assert not any(s.device is victim for s in idx._shards)
        assert idx.handle_device_loss(victim) == ""   # already gone: no-op
        _check_parity(idx, model, rng.normal(size=(8, D)).astype(np.float32), 4)
